"""Per-kernel shape/dtype sweeps: pallas_call(interpret=True) vs ref.py
oracles (deliverable c: per-kernel allclose)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(7)


def ra(*shape, scale=1.0, dtype=jnp.float32):
    return jnp.asarray(RNG.standard_normal(shape) * scale, dtype)


TOL = {jnp.float32: dict(rtol=2e-5, atol=2e-5),
       jnp.bfloat16: dict(rtol=3e-2, atol=3e-2)}


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,h,kvh,s,d", [
    (1, 2, 2, 128, 32),
    (2, 4, 2, 256, 64),
    (1, 8, 1, 256, 16),     # MQA
    (2, 2, 2, 192, 48),     # non-power-of-two s with block 64
])
@pytest.mark.parametrize("causal,window", [(True, 0), (False, 0), (True, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_fwd(b, h, kvh, s, d, causal, window, dtype):
    q, k, v = ra(b, h, s, d, dtype=dtype), ra(b, kvh, s, d, dtype=dtype), \
        ra(b, kvh, s, d, dtype=dtype)
    o = ops.flash_attention(q, k, v, causal, window, 64, 64)
    o_ref = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(o_ref, np.float32), **TOL[dtype])


@pytest.mark.parametrize("causal,window", [(True, 0), (True, 64), (False, 0)])
def test_flash_attention_grads(causal, window):
    b, h, kvh, s, d = 2, 4, 2, 128, 32
    q, k, v = ra(b, h, s, d), ra(b, kvh, s, d), ra(b, kvh, s, d)

    def f(q, k, v):
        return (ops.flash_attention(q, k, v, causal, window, 64, 64) ** 2).sum()

    def fr(q, k, v):
        o = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
        return (o.astype(jnp.float32) ** 2).sum()

    g = jax.grad(f, (0, 1, 2))(q, k, v)
    gr = jax.grad(fr, (0, 1, 2))(q, k, v)
    for a, b_ in zip(g, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   rtol=1e-4, atol=1e-4)


def test_flash_matches_model_chunked_sdpa():
    """Kernel vs the model's chunked (flash-algorithm) jnp path."""
    from repro.models.attention import sdpa
    b, h, kvh, s, d = 1, 4, 2, 256, 32
    q, k, v = ra(b, s, h, d), ra(b, s, kvh, d), ra(b, s, kvh, d)
    o_model = sdpa(q, k, v, causal=True, impl="chunked", chunk=64)
    o_kernel = ops.flash_attention_bshd(q, k, v, causal=True,
                                        block_q=64, block_k=64)
    np.testing.assert_allclose(np.asarray(o_model, np.float32),
                               np.asarray(o_kernel, np.float32),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,h,kvh,s,d", [
    (2, 4, 2, 256, 32), (1, 8, 8, 512, 64), (3, 6, 2, 128, 16)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention(b, h, kvh, s, d, dtype):
    q = ra(b, h, d, dtype=dtype)
    k, v = ra(b, kvh, s, d, dtype=dtype), ra(b, kvh, s, d, dtype=dtype)
    vlen = jnp.asarray(RNG.integers(1, s, size=(b,)), jnp.int32)
    o = ops.decode_attention(q, k, v, vlen, block_s=64)
    o_ref = ref.decode_attention_ref(q, k, v, vlen)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(o_ref, np.float32), **TOL[dtype])


# ---------------------------------------------------------------------------
# rwkv6 / ssd scans vs exact per-step oracles
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,h,s,hd,chunk", [
    (1, 2, 64, 8, 16), (2, 3, 128, 16, 32), (1, 1, 96, 32, 32)])
def test_rwkv6_wkv(b, h, s, hd, chunk):
    r, k, v = (ra(b, h, s, hd, scale=0.5) for _ in range(3))
    logw = -jnp.exp(ra(b, h, s, hd, scale=0.5) - 1.0)
    u = ra(h, hd, scale=0.3)
    o, st = ops.rwkv6_wkv(r, k, v, logw, u, chunk=chunk)
    o_ref, st_ref = ref.rwkv6_wkv_ref(r, k, v, logw, u)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(st), np.asarray(st_ref),
                               rtol=1e-4, atol=1e-4)


def test_rwkv6_model_chunked_matches_oracle():
    """models/rwkv6.wkv_chunked (jnp) vs the per-step oracle."""
    from repro.models.rwkv6 import wkv_chunked
    b, h, s, hd = 2, 2, 64, 8
    r, k, v = (ra(b, s, h, hd, scale=0.5) for _ in range(3))
    logw = -jnp.exp(ra(b, s, h, hd, scale=0.5) - 1.0)
    u = ra(h, hd, scale=0.3)
    st0 = jnp.zeros((b, h, hd, hd), jnp.float32)
    o, st = wkv_chunked(r, k, v, logw, u, st0, 16)
    tr = lambda t: t.transpose(0, 2, 1, 3)
    o_ref, st_ref = ref.rwkv6_wkv_ref(tr(r), tr(k), tr(v), tr(logw), u)
    np.testing.assert_allclose(np.asarray(tr(o)), np.asarray(o_ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(st), np.asarray(st_ref),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("b,h,s,p,n,chunk", [
    (1, 2, 64, 8, 4, 16), (2, 2, 128, 16, 8, 32)])
def test_ssd_scan(b, h, s, p, n, chunk):
    x = ra(b, h, s, p, scale=0.5)
    dt = jnp.abs(ra(b, h, s, scale=0.3)) + 0.1
    a = -jnp.abs(ra(b, h, s, scale=0.3)) * dt
    bmat, cmat = ra(b, s, n, scale=0.5), ra(b, s, n, scale=0.5)
    y, st = ops.ssd_scan(x, dt, a, bmat, cmat, chunk=chunk)
    y_ref, st_ref = ref.ssd_ref(x, dt, a, bmat, cmat)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(st), np.asarray(st_ref),
                               rtol=1e-4, atol=1e-4)


def test_ssd_model_chunked_matches_oracle():
    from repro.models.mamba2 import ssd_chunked
    b, h, s, p, n = 1, 2, 64, 8, 4
    x = ra(b, s, h, p, scale=0.5)
    dt = jnp.abs(ra(b, s, h, scale=0.3)) + 0.1
    a_log = ra(h, scale=0.2)
    bmat, cmat = ra(b, s, n, scale=0.5), ra(b, s, n, scale=0.5)
    st0 = jnp.zeros((b, h, p, n), jnp.float32)
    y, st = ssd_chunked(x, dt, a_log, bmat, cmat, st0, 16)
    a = (-jnp.exp(a_log)[None, None] * dt)  # (b, s, h)
    tr3 = lambda t: t.transpose(0, 2, 1)
    tr4 = lambda t: t.transpose(0, 2, 1, 3)
    y_ref, st_ref = ref.ssd_ref(tr4(x), tr3(dt), tr3(a), bmat, cmat)
    np.testing.assert_allclose(np.asarray(tr4(y)), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(st), np.asarray(st_ref),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# rmsnorm
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("rows,d", [(8, 64), (33, 128), (256, 32)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm(rows, d, dtype):
    x = ra(rows, d, dtype=dtype)
    g = ra(d, scale=0.1)
    o = ops.rmsnorm(x, g)
    o_ref = ref.rmsnorm_ref(x, g)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(o_ref, np.float32), **TOL[dtype])


# ---------------------------------------------------------------------------
# paged attention (scalar-prefetch page tables)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,h,kvh,d,pool,page,maxp", [
    (2, 4, 2, 32, 8, 64, 3), (1, 8, 8, 16, 12, 32, 5), (3, 6, 2, 64, 16, 64, 4)])
def test_paged_attention(b, h, kvh, d, pool, page, maxp):
    from repro.kernels.paged_attention import (paged_attention,
                                               paged_attention_ref)
    q = ra(b, h, d)
    kp, vp = ra(pool, page, kvh, d), ra(pool, page, kvh, d)
    tables = []
    for i in range(b):
        n = int(RNG.integers(1, maxp + 1))
        pages = RNG.choice(pool, size=n, replace=False)
        tables.append(list(pages) + [-1] * (maxp - n))
    table = jnp.asarray(tables, jnp.int32)
    vlen = jnp.asarray([(int((table[i] >= 0).sum())) * page
                        - int(RNG.integers(0, page)) for i in range(b)],
                       jnp.int32)
    o = paged_attention(q, kp, vp, table, vlen)
    o_ref = paged_attention_ref(q, kp, vp, table, vlen)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("b,h,kvh,d,pool,page,maxp", [
    (3, 4, 2, 32, 12, 64, 4), (2, 8, 4, 16, 10, 32, 3)])
def test_paged_ref_matches_dense_oracle(b, h, kvh, d, pool, page, maxp):
    """paged_attention_ref vs the model-layer dense decode attention
    (gqa_decode_sdpa) on random page tables and ragged valid_len: gather
    each request's pages into a contiguous cache and the two must agree."""
    from repro.kernels.paged_attention import paged_attention_ref
    from repro.models.attention import gqa_decode_sdpa

    q = ra(b, h, d)
    kp, vp = ra(pool, page, kvh, d), ra(pool, page, kvh, d)
    tables, vlens = [], []
    for _ in range(b):
        n = int(RNG.integers(1, maxp + 1))
        pages = RNG.choice(pool, size=n, replace=False)
        tables.append(list(pages) + [-1] * (maxp - n))
        vlens.append(n * page - int(RNG.integers(0, page)))  # ragged
    table = jnp.asarray(tables, jnp.int32)
    vlen = jnp.asarray(vlens, jnp.int32)
    o = paged_attention_ref(q, kp, vp, table, vlen)

    for i in range(b):
        own = [p for p in tables[i] if p >= 0]
        # gather this request's pages contiguously: (1, KV, S, d)
        k = kp[jnp.asarray(own)].reshape(len(own) * page, kvh, d)
        v = vp[jnp.asarray(own)].reshape(len(own) * page, kvh, d)
        k = k.transpose(1, 0, 2)[None]
        v = v.transpose(1, 0, 2)[None]
        k_valid = jnp.arange(len(own) * page) < vlens[i]
        o_dense = gqa_decode_sdpa(q[i:i + 1, None], k, v, k_valid)
        np.testing.assert_allclose(np.asarray(o[i]),
                                   np.asarray(o_dense[0, 0]),
                                   rtol=2e-5, atol=2e-5)


def test_paged_matches_contiguous_decode():
    """Paged kernel == dense decode kernel when pages are contiguous."""
    b, h, kvh, d, page, npg = 2, 4, 2, 32, 64, 4
    s = page * npg
    q = ra(b, h, d)
    k, v = ra(b, kvh, s, d), ra(b, kvh, s, d)
    vlen = jnp.asarray([s - 7, s // 2], jnp.int32)
    dense = ops.decode_attention(q, k, v, vlen, block_s=page)
    # build a per-request page pool from the contiguous cache
    kp = k.transpose(0, 2, 1, 3).reshape(b * npg, page, kvh, d)
    vp = v.transpose(0, 2, 1, 3).reshape(b * npg, page, kvh, d)
    table = jnp.arange(b * npg, dtype=jnp.int32).reshape(b, npg)
    paged = ops.paged_attention(q, kp, vp, table, vlen)
    np.testing.assert_allclose(np.asarray(paged), np.asarray(dense),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("pos_last", [0, 5, 23, 24, 37, 100])
def test_paged_attention_ring_window(pos_last):
    """Ring-table sliding-window path (ATTN_LOCAL layers): kernel and
    jnp oracle must both match a dense windowed-attention reference when
    the ring contents are built by last-write-wins over the token
    history (exactly what decode does)."""
    from repro.kernels.paged_attention import (paged_attention,
                                               paged_attention_ref)
    page, ring_pages, kvh, h, d, window, pool = 8, 3, 2, 4, 16, 20, 10
    ring_tokens = ring_pages * page
    vlen = pos_last + 1
    keys = np.asarray(ra(vlen, kvh, d), np.float32)
    vals = np.asarray(ra(vlen, kvh, d), np.float32)
    kp = np.zeros((pool, page, kvh, d), np.float32)
    vp = np.zeros((pool, page, kvh, d), np.float32)
    ring_ids = [7, 2, 5][:min(ring_pages, -(-vlen // page))]
    table = np.full((1, ring_pages), -1, np.int32)
    table[0, :len(ring_ids)] = ring_ids
    for p in range(vlen):          # write each token at its ring slot
        pg, off = divmod(p % ring_tokens, page)
        if pg < len(ring_ids):
            kp[ring_ids[pg], off] = keys[p]
            vp[ring_ids[pg], off] = vals[p]
    q = np.asarray(ra(1, h, d), np.float32)
    args = (jnp.asarray(q), jnp.asarray(kp), jnp.asarray(vp),
            jnp.asarray(table), jnp.asarray([vlen]))
    o_ref = paged_attention_ref(*args, window=window, ring=True)
    o_krn = paged_attention(*args, window=window, ring=True)
    # dense reference over the last `window` tokens
    lo = max(0, vlen - window)
    k = np.repeat(keys[lo:vlen], h // kvh, axis=1)
    v = np.repeat(vals[lo:vlen], h // kvh, axis=1)
    s = np.einsum("hd,shd->hs", q[0], k) * d ** -0.5
    pr = np.exp(s - s.max(-1, keepdims=True))
    pr /= pr.sum(-1, keepdims=True)
    o_dense = np.einsum("hs,shd->hd", pr, v)
    np.testing.assert_allclose(np.asarray(o_ref)[0], o_dense,
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(o_krn)[0], o_dense,
                               rtol=2e-5, atol=2e-5)
