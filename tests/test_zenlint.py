"""zenlint (repro.analysis) test suite.

Four layers:

* fixture cross-check -- every ``tests/zenlint_fixtures/*.py`` carries
  ``# EXPECT[ZLxxx]`` markers on the lines that MUST be flagged; the
  test asserts the analyzer's open findings equal the marker set
  EXACTLY, so the correct-idiom functions in each fixture double as
  negative cases (a false positive fails just as hard as a miss);
* per-rule coverage -- each rule has at least one positive marker and
  at least one clean function in its fixture file;
* suppression semantics -- trailing and standalone directives, the
  mandatory ``-- reason``, wrong-rule ids, docstring mentions;
* the CLI gate -- exit codes 0/1/2, the rule filter, and the seeded
  violation file the CI self-check drives.
"""

import ast
import io
import re
import tokenize
from pathlib import Path

import pytest

from repro.analysis import analyze_source
from repro.analysis.__main__ import main as zenlint_main
from repro.analysis.engine import ENGINE_RULE

REPO = Path(__file__).resolve().parent.parent
FIXTURES = Path(__file__).resolve().parent / "zenlint_fixtures"
RULE_IDS = ["ZL001", "ZL002", "ZL003", "ZL004", "ZL005"]

_EXPECT = re.compile(r"#\s*EXPECT\[([A-Z0-9,\s]+)\]")

#: a minimal ZL001 violation; ``{}`` takes the trailing comment
VIOLATION = "def free_view_ids(pool, req):\n    pool._give(req.pages){}\n"


def expected_findings(source):
    """{(line, rule)} from the EXPECT markers (tokenized, not regexed
    over raw lines, for the same docstring-safety the analyzer has)."""
    out = set()
    for tok in tokenize.generate_tokens(io.StringIO(source).readline):
        if tok.type == tokenize.COMMENT:
            m = _EXPECT.search(tok.string)
            if m:
                for rule in m.group(1).split(","):
                    out.add((tok.start[0], rule.strip()))
    return out


def fixture_source(rule_id):
    (path,) = FIXTURES.glob(f"{rule_id.lower()}_*.py")
    return path.read_text()


# ---------------------------------------------------------------------------
# fixture cross-check
# ---------------------------------------------------------------------------

@pytest.mark.parametrize(
    "name", sorted(p.name for p in FIXTURES.glob("*.py")
                   if p.name != "__init__.py"))
def test_fixture_findings_match_markers_exactly(name):
    source = (FIXTURES / name).read_text()
    expected = expected_findings(source)
    findings = analyze_source(source, path=name)
    actual = {(f.line, f.rule) for f in findings if not f.suppressed}
    assert actual == expected, (
        f"missed: {sorted(expected - actual)}; "
        f"false positives: {sorted(actual - expected)}")
    assert expected, f"{name} carries no positive cases"
    assert not [f for f in findings if f.suppressed], (
        "fixtures must not use suppressions (the suppression tests "
        "below own that behavior)")


@pytest.mark.parametrize("rule_id", RULE_IDS)
def test_each_rule_has_positive_and_negative_fixtures(rule_id):
    source = fixture_source(rule_id)
    expected = expected_findings(source)
    assert any(rule == rule_id for _, rule in expected), (
        f"no positive fixture for {rule_id}")
    # negative coverage: at least one function in the file is entirely
    # clean -- the rule's "correct idiom" demonstration
    flagged = {line for line, _ in expected}
    tree = ast.parse(source)
    funcs = [n for n in ast.walk(tree)
             if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
             and n.name != "__init__"]
    clean = [f.name for f in funcs
             if not any(f.lineno <= line <= f.end_lineno
                        for line in flagged)]
    assert clean, f"no negative (clean) fixture function for {rule_id}"


# ---------------------------------------------------------------------------
# suppression semantics
# ---------------------------------------------------------------------------

def test_trailing_suppression_with_reason_suppresses():
    src = VIOLATION.format("  # zenlint: ignore[ZL001] -- test reason")
    (finding,) = analyze_source(src)
    assert finding.rule == "ZL001"
    assert finding.suppressed
    assert finding.reason == "test reason"


def test_standalone_suppression_covers_next_code_line():
    src = ("def f(pool, req):\n"
           "    # zenlint: ignore[ZL001] -- justification prose that\n"
           "    # continues on a second comment line\n"
           "\n"
           "    pool._give(req.pages)\n")
    (finding,) = analyze_source(src)
    assert finding.suppressed
    assert "justification prose" in finding.reason


def test_reasonless_suppression_is_flagged_and_does_not_suppress():
    src = VIOLATION.format("  # zenlint: ignore[ZL001]")
    findings = analyze_source(src)
    assert sorted(f.rule for f in findings) == [ENGINE_RULE, "ZL001"]
    assert all(not f.suppressed for f in findings)


def test_wrong_rule_id_does_not_suppress():
    src = VIOLATION.format("  # zenlint: ignore[ZL004] -- wrong rule")
    open_zl001 = [f for f in analyze_source(src)
                  if f.rule == "ZL001" and not f.suppressed]
    assert open_zl001


def test_multi_rule_directive_suppresses_each_listed_rule():
    src = VIOLATION.format(
        "  # zenlint: ignore[ZL001, ZL004] -- both listed")
    (finding,) = analyze_source(src)
    assert finding.suppressed


def test_directive_mentioned_in_docstring_is_not_a_directive():
    src = ('def f(pool, req):\n'
           '    """prose mentioning # zenlint: ignore[ZL001] only."""\n'
           '    pool._give(req.pages)\n')
    findings = analyze_source(src)
    assert [f.rule for f in findings] == ["ZL001"]
    assert not findings[0].suppressed


def test_parse_error_is_an_engine_finding():
    (finding,) = analyze_source("def broken(:\n")
    assert finding.rule == ENGINE_RULE
    assert "parse error" in finding.message


# ---------------------------------------------------------------------------
# the CLI gate
# ---------------------------------------------------------------------------

def test_cli_clean_file_exits_zero(tmp_path, capsys):
    clean = tmp_path / "clean.py"
    clean.write_text("x = 1\n")
    assert zenlint_main([str(clean)]) == 0
    assert "zenlint: OK" in capsys.readouterr().out


def test_cli_violation_exits_one_and_reports(tmp_path, capsys):
    bad = tmp_path / "bad.py"
    bad.write_text(VIOLATION.format(""))
    assert zenlint_main([str(bad)]) == 1
    out = capsys.readouterr().out
    assert "ZL001" in out
    assert "zenlint: FAIL" in out


def test_cli_suppressed_finding_passes_but_is_counted(tmp_path, capsys):
    ok = tmp_path / "ok.py"
    ok.write_text(VIOLATION.format("  # zenlint: ignore[ZL001] -- why"))
    assert zenlint_main([str(ok)]) == 0
    assert "1 suppressed" in capsys.readouterr().out


def test_cli_rule_filter_limits_rules(tmp_path):
    bad = tmp_path / "bad.py"
    bad.write_text(VIOLATION.format(""))
    assert zenlint_main(["--rule", "ZL004", str(bad)]) == 0
    assert zenlint_main(["--rule", "ZL001", str(bad)]) == 1


def test_cli_unknown_rule_exits_two(capsys):
    assert zenlint_main(["--rule", "ZL999", "unused"]) == 2
    assert "ZL999" in capsys.readouterr().err


def test_cli_list_rules(capsys):
    assert zenlint_main(["--list-rules", "unused"]) == 0
    out = capsys.readouterr().out
    for rule_id in RULE_IDS:
        assert rule_id in out


def test_cli_seeded_violation_fails_the_gate(capsys):
    """The CI self-check: the gate MUST fail on the seeded file."""
    seeded = FIXTURES / "seeded_violation.py"
    assert zenlint_main([str(seeded)]) == 1
    out = capsys.readouterr().out
    assert "ZL001" in out
    assert "ZL004" in out


def test_repo_tree_is_gate_clean(capsys):
    """The actual CI gate invocation, run as a local regression --
    strict: every suppression in the tree must still be earning its
    keep."""
    paths = [str(REPO / p) for p in ("src", "benchmarks", "examples")]
    assert zenlint_main(["--strict-suppressions"] + paths) == 0, \
        capsys.readouterr().out


# ---------------------------------------------------------------------------
# interprocedural summaries (beyond the EXPECT fixtures: unit checks of
# the summary machinery itself)
# ---------------------------------------------------------------------------

def test_interproc_zl001_helper_sink_param():
    src = ("def _free(pool, ids):\n"
           "    pool._give(ids)\n"
           "def caller(pool, req):\n"
           "    _free(pool, req.pages)\n")
    (finding,) = analyze_source(src)
    assert finding.rule == "ZL001"
    assert finding.line == 4
    assert "_free()" in finding.message


def test_interproc_zl001_ambiguous_name_is_skipped():
    src = ("def _h(pool, ids):\n"
           "    pool._give(ids)\n"
           "class A:\n"
           "    def _h(self, pool, ids):\n"
           "        return len(ids)\n"
           "def caller(pool, req):\n"
           "    _h(pool, req.pages)\n")
    assert analyze_source(src) == []


def test_interproc_zl001_known_names_not_summarized():
    """A local def shadowing a pool verb must not override the built-in
    vocabulary (the real verbs are polymorphic across PoolView)."""
    src = ("def to_physical(pool, ids):\n"
           "    pool._give(ids)\n"
           "def caller(pool, req):\n"
           "    return to_physical(pool, req.pages)\n")
    assert analyze_source(src) == []


def test_interproc_zl005_relay_vs_internal_consumption():
    relay = ("def _relay(pool, req):\n"
             "    return pool.reclaim(req)\n"
             "def caller(pool, req):\n"
             "    _relay(pool, req)\n")
    (finding,) = analyze_source(relay)
    assert finding.rule == "ZL005" and finding.line == 4
    consumed = ("def _detach(cache, nodes, stats):\n"
                "    released = cache.unpin(nodes)\n"
                "    stats.append(released)\n"
                "    return released\n"
                "def caller(cache, req, stats):\n"
                "    _detach(cache, req.prefix_nodes, stats)\n")
    assert analyze_source(consumed) == []


# ---------------------------------------------------------------------------
# output formats (exit codes must be identical across all three)
# ---------------------------------------------------------------------------

def test_cli_format_json(tmp_path, capsys):
    import json

    bad = tmp_path / "bad.py"
    bad.write_text(VIOLATION.format(""))
    assert zenlint_main(["--format", "json", str(bad)]) == 1
    doc = json.loads(capsys.readouterr().out)
    assert doc["open"] == 1 and doc["ok"] is False
    (f,) = doc["findings"]
    assert f["rule"] == "ZL001" and f["path"] == str(bad)
    ok = tmp_path / "ok.py"
    ok.write_text(VIOLATION.format("  # zenlint: ignore[ZL001] -- why"))
    assert zenlint_main(["--format", "json", str(ok)]) == 0
    doc = json.loads(capsys.readouterr().out)
    assert doc["ok"] is True and doc["suppressed"] == 1
    assert doc["findings"][0]["reason"] == "why"


def test_cli_format_github(tmp_path, capsys):
    bad = tmp_path / "bad.py"
    bad.write_text(VIOLATION.format(""))
    assert zenlint_main(["--format", "github", str(bad)]) == 1
    out = capsys.readouterr().out
    assert f"::error file={bad},line=" in out
    assert "title=zenlint ZL001::" in out
    ok = tmp_path / "ok.py"
    ok.write_text(VIOLATION.format("  # zenlint: ignore[ZL001] -- why"))
    assert zenlint_main(["--format", "github", "--show-suppressed",
                         str(ok)]) == 0
    out = capsys.readouterr().out
    assert "::notice" in out and "::error" not in out


def test_cli_format_github_escapes_newlines(tmp_path, capsys):
    """Workflow-command data is %-escaped; a multi-line message must
    stay a single annotation line."""
    from repro.analysis.__main__ import _gh_escape

    assert _gh_escape("a\nb%c") == "a%0Ab%25c"


# ---------------------------------------------------------------------------
# stale-suppression detection (--strict-suppressions)
# ---------------------------------------------------------------------------

def test_stale_suppression_flagged_only_in_strict_mode(tmp_path, capsys):
    src = "x = 1  # zenlint: ignore[ZL001] -- long-gone finding\n"
    f = tmp_path / "stale.py"
    f.write_text(src)
    assert zenlint_main([str(f)]) == 0
    capsys.readouterr()
    assert zenlint_main(["--strict-suppressions", str(f)]) == 1
    out = capsys.readouterr().out
    assert "stale suppression of [ZL001]" in out
    assert ENGINE_RULE in out


def test_live_suppression_passes_strict_mode(tmp_path):
    f = tmp_path / "live.py"
    f.write_text(VIOLATION.format("  # zenlint: ignore[ZL001] -- why"))
    assert zenlint_main(["--strict-suppressions", str(f)]) == 0


def test_strict_mode_respects_rule_filter(tmp_path):
    """A --rule-filtered run must not call another rule's directive
    stale: that rule never got a chance to consume it."""
    f = tmp_path / "other.py"
    f.write_text(VIOLATION.format("  # zenlint: ignore[ZL001] -- why"))
    assert zenlint_main(["--strict-suppressions", "--rule", "ZL004",
                         str(f)]) == 0
