"""Unit tests for the dry-run/roofline machinery that doesn't need 512
devices: HLO collective parsing, two-point extrapolation, input specs,
mesh specs, and roofline aggregation."""


import jax
import pytest

# NOTE: importing repro.launch.dryrun sets XLA_FLAGS *before* jax is
# initialized elsewhere in this process -- but jax is already imported by
# conftest, so the env var has no effect on device count here (it only
# matters for fresh processes).  Safe to import for its pure helpers.
from repro.launch import dryrun
from repro.launch.input_specs import input_specs
from repro.configs import SHAPES, get_config
from repro.core.materializer import MESHES


HLO_SAMPLE = """
ENTRY %main {
  %ag = bf16[16,1024]{1,0} all-gather(%p0), replica_groups={...}
  %ar.1 = f32[256]{0} all-reduce(%x), to_apply=%add
  %ars = f32[512]{0} all-reduce-start(%y), to_apply=%add
  %ard = f32[512]{0} all-reduce-done(%ars)
  %rs = (bf16[8,128]{1,0}, bf16[8,128]{1,0}) reduce-scatter(%a, %b)
  %a2a = s8[64,64]{1,0} all-to-all(%c)
  %cp = bf16[32]{0} collective-permute(%d)
  %dot = f32[128,128]{1,0} dot(%e, %f)
}
"""


def test_collective_stats_parses_ops_and_bytes():
    st = dryrun.collective_stats(HLO_SAMPLE)
    assert st["all-gather"]["count"] == 1
    assert st["all-gather"]["bytes"] == 16 * 1024 * 2
    # -start counted once, -done skipped
    assert st["all-reduce"]["count"] == 2
    assert st["all-reduce"]["bytes"] == 256 * 4 + 512 * 4
    # tuple-typed reduce-scatter sums both operands
    assert st["reduce-scatter"]["count"] == 1
    assert st["reduce-scatter"]["bytes"] == 2 * 8 * 128 * 2
    assert st["all-to-all"]["bytes"] == 64 * 64
    assert st["collective-permute"]["count"] == 1


def test_merge_costs_extrapolation_and_clamp():
    c1 = {"flops": 100.0, "bytes accessed": 50.0}
    c2 = {"flops": 160.0, "bytes accessed": 45.0}  # decreasing -> clamp
    out = dryrun._merge_costs(c1, c2, nb=10)
    assert out["flops"] == 100.0 + 9 * 60.0
    assert out["bytes accessed"] == 50.0  # clamped per-block delta


@pytest.mark.parametrize("arch,shape", [
    ("mistral-nemo-12b", "train_4k"),
    ("whisper-base", "train_4k"),
    ("phi-3-vision-4.2b", "train_4k"),
    ("rwkv6-7b", "decode_32k"),
    ("gemma3-12b", "prefill_32k"),
])
def test_input_specs_shapes(arch, shape):
    cfg = get_config(arch)
    sh = SHAPES[shape]
    ins = input_specs(cfg, sh)
    assert all(isinstance(v, jax.ShapeDtypeStruct) for v in ins.values())
    if sh.kind == "train":
        b, s = ins["tokens"].shape
        assert b == sh.global_batch
        n_img = cfg.num_image_tokens if cfg.family == "vlm" else 0
        assert s == sh.seq_len - n_img
        assert ins["labels"].shape == ins["tokens"].shape
        if cfg.is_encdec:
            assert ins["enc_feats"].shape == (
                b, cfg.encoder_seq_len, cfg.d_model)
    elif sh.kind == "decode":
        assert ins["tokens"].shape == (sh.global_batch, 1)
        assert ins["pos"].shape == ()


def test_mesh_specs_consistent():
    sp, mp = MESHES["single_pod"], MESHES["multi_pod"]
    assert sp.num_devices == 256 and mp.num_devices == 512
    assert sp.axes == ("data", "model")
    assert mp.axes == ("pod", "data", "model")
    assert mp.axis_size("pod") == 2
    assert sp.axis_size("nonexistent") == 1
    assert sp.batch_capable_axes == ("data",)
    assert mp.batch_capable_axes == ("pod", "data")


def test_roofline_terms_math():
    from repro.launch.dryrun import roofline_terms
    cfg = get_config("tinyllama-1.1b")
    shape = SHAPES["train_4k"]
    mesh = MESHES["single_pod"]
    result = {
        "cost_extrapolated": {"flops": 197e12, "bytes accessed": 819e9},
        "collectives_extrapolated": {
            "all-reduce": {"count": 1, "bytes": 50e9}},
    }
    r = roofline_terms(result, cfg, shape, mesh)
    assert abs(r["compute_term_s"] - 1.0) < 1e-6
    assert abs(r["memory_term_s"] - 1.0) < 1e-6
    assert abs(r["collective_term_s"] - 1.0) < 1e-6
    assert r["dominant"] in ("compute", "memory", "collective")
    assert r["model_flops"] > 0
    assert 0 < r["mfu_upper_bound"] < 10


def test_roofline_artifacts_loadable_and_consistent():
    """Every produced dry-run artifact parses and carries coherent terms."""
    from repro.roofline.analysis import load_cells, roofline_table
    cells = load_cells()
    if not cells:
        pytest.skip("no dry-run artifacts present")
    ok = [c for c in cells if c.get("status") == "ok"]
    assert len(ok) >= 1
    for c in ok:
        r = c["roofline"]
        assert r["compute_term_s"] >= 0
        assert r["memory_term_s"] >= 0
        assert r["collective_term_s"] >= 0
        assert r["dominant"] in ("compute", "memory", "collective")
        assert c["memory"]["peak_tpu_adjusted"] <= c["memory"]["peak_bytes"]
        assert c["plan"]["notes"], "every plan must carry its audit trail"
    rows = roofline_table(cells, "single_pod")
    assert rows and all("advice" in r for r in rows)


def test_all_runnable_cells_have_artifacts():
    """The sweep must cover every runnable (arch x shape x mesh) cell."""
    import os
    from repro.configs import all_cells
    art = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")
    if not os.path.isdir(art) or not os.listdir(art):
        pytest.skip("no dry-run artifacts present")
    cells, skips = all_cells()
    missing = []
    for arch, shape, mesh in cells:
        path = os.path.join(art, f"{arch}__{shape}__{mesh}.json")
        if not os.path.exists(path):
            missing.append((arch, shape, mesh))
    assert not missing, f"missing dry-run artifacts: {missing[:5]}"
    # documented skips: 7 pure-full-attention archs x long_500k
    assert len(skips) == 7
