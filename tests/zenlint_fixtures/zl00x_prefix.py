"""Prefix-cache fixtures: the ZL001 + ZL005 extensions of PR 7.

Never imported at runtime -- parsed by the analyzer only.  The prefix
cache introduces a second class of physical page ids that legitimately
lives on requests (``req.shared_pages``, fed by ``cache_donate`` /
``PrefixMatch.phys_pages``) and three new accounting receipts
(``pin``/``unpin``/``cow_grant``).  Lines that MUST be flagged carry an
``# EXPECT[...]`` marker; every other line must stay clean, so the
correct idioms below double as negative cases.
"""


# -- ZL001 violations: the new physical provenance sources ------------------

def view_ids_assigned_to_shared_pages(req):
    req.shared_pages = req.pages  # EXPECT[ZL001]


def view_ids_extended_into_shared_pages(pool, req):
    req.shared_pages.extend(pool.cow_grant())  # EXPECT[ZL001]


def shared_pages_translated_again(view, req):
    return view.to_physical(req.shared_pages)  # EXPECT[ZL001]


def match_pages_stored_as_view_ids(m, req):
    ids = list(m.phys_pages)
    req.pages.extend(ids)  # EXPECT[ZL001]


def donated_ids_freed_as_view_ids(self, pool, req):
    phys = pool.cache_donate(req.pages)
    return self._phys(phys)  # EXPECT[ZL001]


# -- ZL001 correct idioms (must NOT be flagged) -----------------------------

def correct_donation(pool, req):
    phys = pool.cache_donate(req.pages)
    req.shared_pages.extend(phys)


def correct_mixed_page_table(view, req):
    table = list(req.shared_pages) + view.to_physical(req.pages)
    return page_table(pages=table)


def correct_shared_free(pool, req):
    pool._give(req.shared_pages)


# -- ZL005 violations: pin/unpin/cow_grant receipts -------------------------

def pin_discarded(cache, toks):
    cache.pin(toks)  # EXPECT[ZL005]


def pin_bound_but_never_used(cache, toks):
    m = cache.pin(toks)  # EXPECT[ZL005]


def unpin_count_discarded(cache, req):
    cache.unpin(req.prefix_nodes)  # EXPECT[ZL005]


def cow_grant_dropped(pool):
    got = pool.cow_grant()  # EXPECT[ZL005]


def early_return_strands_pin(cache, toks, fast):
    m = cache.pin(toks)
    if fast:
        return None  # EXPECT[ZL005]
    return m


# -- ZL005 correct idioms (must NOT be flagged) -----------------------------

def correct_pin_attach(cache, toks, req):
    m = cache.pin(toks)
    req.prefix_nodes = m.nodes
    req.shared_pages = list(m.phys_pages)


def correct_unpin_into_stats(cache, stats, req):
    released = cache.unpin(req.prefix_nodes)
    stats["prefix_unpinned"] += released


def correct_unpin_augassign(self, cache, m):
    self.reattach_unpins += cache.unpin(m.nodes)


def correct_cow_grant_checked(pool):
    got = pool.cow_grant()
    if got is None:
        return None
    return got[0]
