"""ZL004 fixtures: host synchronization inside serving hot paths.

Device values are names assigned from jitted callables or ``jnp.*``
calls; the one legal sync idiom is a single batched ``np.asarray`` whose
RESULT is then indexed host-side (the fetch itself is still flagged --
the real runner carries the justified suppression).
"""

import jax
import jax.numpy as jnp
import numpy as np


def _decode_fn(params, toks):
    return toks


class SyncRunner:
    def __init__(self):
        self._decode = jax.jit(_decode_fn)

    # -- violations ---------------------------------------------------------

    def decode(self, req):
        logits = self._decode(self.params, req.tokens)
        tok = logits.item()  # EXPECT[ZL004]
        host = jax.device_get(logits)  # EXPECT[ZL004]
        val = int(logits[0])  # EXPECT[ZL004]
        if logits[0] > 0:  # EXPECT[ZL004]
            tok = 0
        return tok, host, val

    def prefill(self, req):
        probs = jnp.exp(req.logits)
        return float(probs[0])  # EXPECT[ZL004]

    # -- correct idioms (must NOT be flagged) -------------------------------

    def _decode_fn(self, req):
        logits = self._decode(self.params, req.tokens)
        fetched = np.asarray(logits)  # EXPECT[ZL004]
        first = int(fetched[0])
        if fetched[0] > 0:
            first += 1
        return first

    def report(self, req):
        logits = self._decode(self.params, req.tokens)
        return float(logits[0])
