"""ZL002 fixtures: reading a buffer after jit donated it.

Mirrors the PagedRunner shape: the jitted step is bound once in
``__init__`` with ``donate_argnums``, and the KV page arrays are passed
in -- after which the only safe read is through a rebinding from the
call's own result.
"""

import jax


def _decode_fn(params, toks, k_pages, v_pages):
    return toks, k_pages, v_pages


class FixtureRunner:
    def __init__(self):
        self._decode = jax.jit(_decode_fn, donate_argnums=(2, 3))

    # -- violations ---------------------------------------------------------

    def read_after_donation(self):
        nxt, _, _ = self._decode(self.params, self.toks,
                                 self.store.k_pages, self.store.v_pages)
        return nxt, self.store.k_pages[0]  # EXPECT[ZL002]

    def call_with_dead_buffer(self):
        nxt, _, _ = self._decode(self.params, self.toks,
                                 self.store.k_pages, self.store.v_pages)
        self.snapshot(self.store.v_pages)  # EXPECT[ZL002]
        return nxt

    # -- correct idioms (must NOT be flagged) -------------------------------

    def rebind_from_result(self):
        nxt, self.store.k_pages, self.store.v_pages = self._decode(
            self.params, self.toks,
            self.store.k_pages, self.store.v_pages)
        return nxt, self.store.k_pages[0]

    def rebind_later_from_out(self):
        out = self._decode(self.params, self.toks,
                           self.store.k_pages, self.store.v_pages)
        self.store.k_pages = out[1]
        self.store.v_pages = out[2]
        return self.store.k_pages[0], self.store.v_pages[0]

    def undonated_args_stay_live(self):
        nxt, self.store.k_pages, self.store.v_pages = self._decode(
            self.params, self.toks,
            self.store.k_pages, self.store.v_pages)
        return nxt, self.params
