"""Interprocedural fixtures: ZL001/ZL005 must follow ids and receipts
through locally defined helpers.

Each violating caller here was INVISIBLE to the per-function pass (the
helper's body is legal in isolation; the caller never names a sink) --
these pin the module-summary upgrade.  The correct idioms pin its
restraint: internal translation, internal consumption, dict custody,
and ambiguous names must not be flagged.
"""


class FixtureRunner:

    # -- helpers (legal in isolation) ---------------------------------------

    def _free_pages(self, pool, ids):
        """Forwards ids straight to a physical sink."""
        pool._give(ids)

    def _push(self, pool, ids):
        pool._give(ids)

    def _relay(self, pool, ids):
        """Chain: sink is two hops away (needs the fixpoint)."""
        self._push(pool, ids)

    def _ident(self, ids):
        """Pass-through: the return carries the argument's taint."""
        return ids

    def _translated(self, pool, req):
        """Fixed return taint: always physical."""
        return pool.to_physical(req.pages)

    def _park_all(self, pool, req):
        """Pure receipt relay: the caller owns the reclaim receipt."""
        return pool.reclaim(req)

    def _park_outer(self, pool, req):
        """Relay of a relay (needs the fixpoint)."""
        return self._park_all(pool, req)

    # -- ZL001 violations across the helper boundary ------------------------

    def free_view_ids_via_helper(self, pool, req):
        self._free_pages(pool, req.pages)  # EXPECT[ZL001]

    def free_view_ids_via_chain(self, pool, req):
        self._relay(pool, req.local_pages)  # EXPECT[ZL001]

    def free_passthrough_result(self, pool, req):
        pool._give(self._ident(req.pages))  # EXPECT[ZL001]

    def store_phys_return_on_request(self, pool, req):
        req.pages = self._translated(pool, req)  # EXPECT[ZL001]

    def double_translate_helper_result(self, pool, req):
        return pool.to_physical(self._translated(pool, req))  # EXPECT[ZL001]

    # -- ZL005 violations across the helper boundary ------------------------

    def preempt_discards_relayed_receipt(self, pool, victim):
        self._park_all(pool, victim)  # EXPECT[ZL005]

    def preempt_discards_chained_receipt(self, pool, victim):
        self._park_outer(pool, victim)  # EXPECT[ZL005]

    def relayed_receipt_never_consumed(self, pool, victim):
        ids = self._park_all(pool, victim)  # EXPECT[ZL005]
        self.count += 1

    # -- correct idioms (must NOT be flagged) -------------------------------

    def free_translated_ids_via_helper(self, pool, req):
        self._free_pages(pool, pool.to_physical(req.pages))

    def helper_translates_internally(self, pool, req):
        # _free_safely's body converts before sinking, so view ids are
        # the correct currency at this call site
        self._free_safely(pool, req.pages)

    def _free_safely(self, pool, ids):
        pool._give(pool.to_physical(ids))

    def relayed_receipt_consumed(self, pool, victim):
        ids = self._park_all(pool, victim)
        self.snapshot(ids)
        return ids

    def _detach(self, cache, nodes):
        # consumes its own receipt (folds into stats): the return value
        # is informational, so callers may ignore it
        released = cache.unpin(nodes)
        self.count += released
        return released

    def detach_ignoring_count(self, cache, req):
        self._detach(cache, req.prefix_nodes)

    def _park_info(self, pool, req):
        # keeps custody: the receipt travels inside a dict this helper's
        # caller receives whole
        ids = pool.reclaim(req)
        return {"req": req.req_id, "ids": ids}


class OtherRunner:
    """A second def of ``_mixed`` makes the name ambiguous module-wide:
    no summary may be built for it, so neither caller is flagged."""

    def _mixed(self, pool, ids):
        pool._give(ids)


class ThirdRunner:

    def _mixed(self, pool, ids):
        self.log(ids)

    def call_ambiguous_helper(self, pool, req):
        self._mixed(pool, req.pages)
