"""Deliberately broken file for the CI self-check: the zenlint gate must
exit non-zero on it, proving the gate actually fails when an invariant
is violated (a gate that cannot fail gates nothing)."""


def free_view_ids(pool, req):
    pool._give(req.pages)  # EXPECT[ZL001]


class SeededRunner:
    def decode(self, req):
        import jax.numpy as jnp
        logits = jnp.exp(req.logits)
        return logits.item()  # EXPECT[ZL004]
