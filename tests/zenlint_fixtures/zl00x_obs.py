"""Observability fixtures: ZL004 vs the ``repro.obs`` tracer idiom.

Never imported at runtime -- parsed by the analyzer only.  The tracing
discipline (obs/trace.py) is guard-and-append with HOST-scalar args; the
tempting mistake is stuffing a device value into an event's args dict,
which forces a transfer+sync inside the decode/prefill hot path -- the
exact stall ZL004 exists to catch.  Lines that MUST be flagged carry an
``# EXPECT[ZL004]`` marker; the correct idioms below double as negative
cases (shape/len/dataclass-int args never touch the device).
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import trace as obs_trace


def _decode_fn(params, toks):
    return toks


class TracedRunner:
    def __init__(self):
        self._decode = jax.jit(_decode_fn)

    # -- violations: tracer args that sync a device value -------------------

    def decode(self, req):
        logits = self._decode(self.params, req.tokens)
        t = obs_trace.TRACER
        if t is not None:
            tok = int(logits[0])  # EXPECT[ZL004]
            t.instant("engine", "decode_step", req.req_id, {"tok": tok})
        return logits

    def prefill(self, req):
        scores = jnp.exp(req.logits)
        t = obs_trace.TRACER
        if t is not None:
            t.instant("request", "prefill", req.req_id,
                      {"score": float(scores[0])})  # EXPECT[ZL004]
        return scores

    def _decode_fn(self, req):
        logits = self._decode(self.params, req.tokens)
        t = obs_trace.TRACER
        if t is not None:
            host = np.asarray(logits)  # EXPECT[ZL004]
            t.instant("compile", "decode_trace", None,
                      {"first": host[0]})
        return logits


class CleanTracedRunner:
    def __init__(self):
        self._decode = jax.jit(_decode_fn)

    # -- correct idioms (must NOT be flagged): host-scalar args only --------

    def decode(self, req):
        logits = self._decode(self.params, req.tokens)
        t = obs_trace.TRACER
        if t is not None:
            t.instant("engine", "decode_step", req.req_id,
                      {"batch": logits.shape[0], "queue": len(req.queue)})
        return logits

    def prefill(self, req):
        toks = self._decode(self.params, req.tokens)
        t = obs_trace.TRACER
        if t is not None:
            t.instant("request", "prefill", req.req_id,
                      {"prompt_len": req.prompt_len,
                       "tokens": toks.shape[1]})
        return toks
