"""ZL003 fixtures: per-request values becoming jit compile keys.

Hot-path scoping comes from the class/method naming convention
(``*Runner.decode/prefill`` etc.), so the violating methods live on a
``...Runner`` class and the same patterns outside a hot path are legal.
"""

import jax
import numpy as np


def _prefill_fn(params, toks, width):
    return toks


def _step_fn(toks):
    return toks


def _next_pow2(n):
    m = 1
    while m < n:
        m *= 2
    return m


class HazardRunner:
    def __init__(self):
        self._prefill = jax.jit(_prefill_fn, static_argnums=(2,))
        self._step = jax.jit(_step_fn)

    # -- violations ---------------------------------------------------------

    def prefill(self, req):
        return self._prefill(self.params, req.tokens, req.prompt_len)  # EXPECT[ZL003]

    def _prefill_fn(self, req):
        fresh = jax.jit(_step_fn)  # EXPECT[ZL003]
        return fresh(req.tokens)

    def decode(self, running, req):
        toks = req.tokens
        out = self._step(toks)  # EXPECT[ZL003]
        buf = np.zeros((len(running), 8))  # EXPECT[ZL003]
        return out, buf

    # -- correct idioms (must NOT be flagged) -------------------------------

    def _decode_fn(self, req):
        width = _next_pow2(req.prompt_len)
        staged = np.zeros((self.max_batch, 8))
        padded = ((req.prompt_len + 7) // 8) * 8
        return self._prefill(self.params, staged, width), padded


class ColdHelper:
    """Same patterns OUTSIDE a hot path: legal (setup code may stage
    per-request shapes; it runs once, not per token)."""

    def warmup(self, req):
        probe = jax.jit(_step_fn)
        return probe(np.zeros((req.prompt_len,)))
