"""ZL001 fixtures: view-local vs physical page-id provenance.

Never imported at runtime -- parsed by the analyzer only.  Lines that
MUST be flagged carry an ``# EXPECT[ZL001]`` marker; every other line
must stay clean (the test asserts exact set equality, so the correct
idioms double as negative cases).
"""


# -- violations -------------------------------------------------------------

def free_view_ids_into_pool(pool, req):
    pool._give(req.pages)  # EXPECT[ZL001]


def kernel_sees_view_ids(req):
    return page_table(pages=req.pages)  # EXPECT[ZL001]


def double_translation(view, req):
    phys = view.to_physical(req.pages)
    return view.to_physical(phys)  # EXPECT[ZL001]


def physical_ids_stored_on_request(view, req):
    phys = view.to_physical(req.pages)
    req.pages = phys  # EXPECT[ZL001]


def physical_ids_extended_onto_request(view, req):
    phys = view.to_physical_local(req.local_pages)
    req.local_pages.extend(phys)  # EXPECT[ZL001]


def view_ids_pushed_onto_physical_free_list(self, req):
    self.free_local.extend(req.pages)  # EXPECT[ZL001]


def view_taint_through_list_copy(pool, req):
    ids = list(req.pages)
    pool._give(ids)  # EXPECT[ZL001]


# -- correct idioms (must NOT be flagged) -----------------------------------

def correct_free(pool, view, req):
    phys = view.to_physical(req.pages)
    pool._give(phys)


def correct_kernel(view, req):
    return page_table(pages=view.to_physical(req.pages))


def correct_grant_extends_view_ids(view, req):
    req.pages.extend(view._alloc(2))


def correct_physical_free_list(self, view, req):
    self.free_local.extend(view.to_physical_local(req.local_pages))


def page_table(pages=None):
    return pages
