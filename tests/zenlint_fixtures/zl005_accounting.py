"""ZL005 fixtures: reclaim/park receipts must be consumed on every path."""


class FixtureScheduler:

    # -- violations ---------------------------------------------------------

    def preempt_discards_receipt(self, pool, victim):
        pool.reclaim(victim)  # EXPECT[ZL005]

    def park_then_early_return(self, scheduler, app, urgent):
        freed = scheduler.park(app)
        if urgent:
            return None  # EXPECT[ZL005]
        self.ledger.append(freed)
        return freed

    def reclaim_never_consumed(self, pool, victim):
        ids = pool.reclaim(victim)  # EXPECT[ZL005]
        self.count += 1

    # -- correct idioms (must NOT be flagged) -------------------------------

    def reclaim_and_snapshot(self, pool, victim):
        ids = pool.reclaim(victim)
        self.snapshot(ids)
        return ids

    def park_and_propagate(self, scheduler, app):
        return scheduler.park(app)

    def drain_consumed_in_loop(self, pool):
        ids = pool.drain()
        for page in ids:
            self.copy_out(page)

    def regrant_checked(self, pool, app):
        ok = pool.regrant(app)
        if not ok:
            self.requeue(app)
