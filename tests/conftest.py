"""Shared fixtures.  NOTE: no XLA_FLAGS device-count override here --
smoke tests and benches must see the real single CPU device; only
launch/dryrun.py forces 512 host devices (in its own process)."""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import pytest

from repro.configs.reduced import reduced_config  # noqa: F401  (re-export)


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
