"""Shared fixtures.  NOTE: no XLA_FLAGS device-count override here --
smoke tests and benches must see the real single CPU device; only
launch/dryrun.py forces 512 host devices (in its own process)."""

import dataclasses
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import pytest

from repro.configs.base import ModelConfig, get_config


def reduced_config(cfg: ModelConfig, **extra) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    kw = dict(
        num_layers=len(cfg.pattern),
        d_model=64,
        num_heads=4,
        num_kv_heads=(max(1, min(cfg.num_kv_heads, 4))
                      if cfg.num_kv_heads < cfg.num_heads else 4),
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        sliding_window=min(cfg.sliding_window, 8) if cfg.sliding_window else 0,
        encoder_seq_len=16 if cfg.is_encdec else 0,
        num_encoder_layers=2 if cfg.is_encdec else 0,
        num_image_tokens=8 if cfg.family == "vlm" else 0,
        max_context=1 << 30,
    )
    if cfg.moe:
        kw["moe"] = dataclasses.replace(
            cfg.moe, num_experts=8, top_k=2, d_expert=32,
            d_shared_expert=64 if cfg.moe.num_shared_experts else 0)
    if cfg.ssm:
        kw["ssm"] = dataclasses.replace(cfg.ssm, state_dim=8, head_dim=8,
                                        chunk_size=4)
    kw.update(extra)
    return cfg.scaled(**kw)


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
