"""Per-architecture smoke tests: reduced config of the same family, one
forward/train step + one prefill/decode step on CPU, asserting output
shapes and finiteness (deliverable f)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import reduced_config
from repro.configs import ALL_ARCHS, get_config
from repro.models import ImplConfig, build_model
from repro.training import optimizer as opt
from repro.training.train_step import make_train_step
from repro.core.materializer import Plan, SINGLE_POD

B, S = 2, 16


def _batch(cfg, rng):
    batch = {
        "tokens": jax.random.randint(rng, (B, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(rng, (B, S), 0, cfg.vocab_size),
    }
    if cfg.is_encdec:
        batch["enc_feats"] = jax.random.normal(
            rng, (B, cfg.encoder_seq_len, cfg.d_model), jnp.bfloat16)
    if cfg.family == "vlm":
        batch["img_feats"] = jax.random.normal(
            rng, (B, cfg.num_image_tokens, 1024), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_loss(arch, rng):
    cfg = reduced_config(get_config(arch))
    model = build_model(cfg, ImplConfig(scan_chunk=4, remat="none"))
    params = model.init_params(rng)
    loss, metrics = jax.jit(model.loss_fn)(params, _batch(cfg, rng))
    assert loss.shape == ()
    assert np.isfinite(float(loss)), f"{arch}: non-finite loss"
    assert float(loss) > 0


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_train_step_updates_params(arch, rng):
    cfg = reduced_config(get_config(arch))
    model = build_model(cfg, ImplConfig(scan_chunk=4, remat="none"))
    params = model.init_params(rng)
    opt_state = opt.init_opt_state(params)
    plan = Plan(arch, "train_4k", SINGLE_POD, microbatch=1, remat="none")
    step = jax.jit(make_train_step(model, plan))
    new_params, new_opt, metrics = step(params, opt_state, _batch(cfg, rng))
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # at least one parameter changed
    changed = any(
        not np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(new_params)))
    assert changed, f"{arch}: no parameter moved"
    assert int(new_opt["count"]) == 1


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_prefill_decode_consistency(arch, rng):
    """Greedy decode after prefill(S) must match prefill(S+1)'s last logits."""
    cfg = reduced_config(get_config(arch))
    model = build_model(cfg, ImplConfig(scan_chunk=4, remat="none"))
    params = model.init_params(rng)
    toks = jax.random.randint(rng, (B, S + 1), 0, cfg.vocab_size)
    batch_s = dict(_batch(cfg, rng), tokens=toks[:, :S])
    batch_s.pop("labels")
    batch_s1 = dict(batch_s, tokens=toks)

    cache_len = 32
    logits_s, cache = jax.jit(
        lambda p, b: model.prefill(p, b, cache_len))(params, batch_s)
    pos = jnp.asarray(S + (cfg.num_image_tokens or 0), jnp.int32)
    logits_dec, _ = jax.jit(model.decode_step)(
        params, toks[:, S:S + 1], cache, pos)
    logits_full, _ = jax.jit(
        lambda p, b: model.prefill(p, b, cache_len + 1))(params, batch_s1)

    a = np.asarray(logits_dec[:, -1], np.float32)
    b = np.asarray(logits_full[:, -1], np.float32)
    # bf16 compute: compare top-1 agreement and value closeness
    np.testing.assert_allclose(a, b, rtol=0.15, atol=0.3)
    assert (np.argmax(a, -1) == np.argmax(b, -1)).mean() >= 0.95, arch


@pytest.mark.parametrize("arch", ["gemma3-12b", "zamba2-2.7b", "rwkv6-7b"])
def test_multi_step_decode(arch, rng):
    """8 consecutive decode steps stay finite (ring buffers, states)."""
    cfg = reduced_config(get_config(arch))
    model = build_model(cfg, ImplConfig(scan_chunk=4, remat="none"))
    params = model.init_params(rng)
    batch = {"tokens": jax.random.randint(rng, (B, S), 0, cfg.vocab_size)}
    logits, cache = jax.jit(lambda p, b: model.prefill(p, b, 64))(params, batch)
    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    dec = jax.jit(model.decode_step)
    for i in range(8):
        logits, cache = dec(params, tok, cache, jnp.asarray(S + i, jnp.int32))
        assert np.isfinite(np.asarray(logits, np.float32)).all(), (arch, i)
        tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)


def test_param_counts_match_full_configs():
    """Full-size analytic param counts are in the right ballpark."""
    import repro.core.profiles as prof
    expect = {
        "tinyllama-1.1b": (0.9e9, 1.4e9),
        "mistral-nemo-12b": (11e9, 14e9),
        "command-r-35b": (28e9, 40e9),
        "dbrx-132b": (120e9, 145e9),
        "rwkv6-7b": (6e9, 9e9),
        "gemma3-12b": (9e9, 14e9),
        "qwen2-moe-a2.7b": (12e9, 18e9),   # total (incl all experts+pad)
        "zamba2-2.7b": (2.0e9, 3.4e9),
        "phi-3-vision-4.2b": (3.4e9, 4.5e9),
        "whisper-base": (0.05e9, 0.12e9),
    }
    for arch, (lo, hi) in expect.items():
        n = prof.model_param_count(get_config(arch))
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B params out of [{lo/1e9}, {hi/1e9}]"


def test_active_params_moe():
    import repro.core.profiles as prof
    cfg = get_config("dbrx-132b")
    total = prof.model_param_count(cfg)
    active = prof.model_active_param_count(cfg)
    assert active < total * 0.45
    assert active > total * 0.15
