"""End-to-end tests for the resource-centric runtime API.

The acceptance behaviour: one Cluster accepts a reduced train app and a
reduced serve app, runs real steps through the JaxExecutor, scales a data
component up at runtime, and after release the pod accounting returns
EXACTLY to its initial state (no reservation or free-byte leaks)."""

import numpy as np

from repro.core.history import HistoryStore
from repro.core.scheduler import GB, GlobalScheduler, Job, PodState
from repro.runtime import (Application, Cluster, JaxExecutor, NullExecutor,
                           ServeOptions, measure_cluster_throughput,
                           replay_trace)
from repro.serving.kv_cache import Request


# ---------------------------------------------------------------------------
# the end-to-end lifecycle (acceptance criterion)
# ---------------------------------------------------------------------------

def test_train_and_serve_share_one_cluster():
    """Submit train + serve to ONE cluster, run real steps, scale, release:
    capacity must be restored exactly."""
    hist = HistoryStore()
    cluster = Cluster(pods=1, history=hist, executor=JaxExecutor())
    cap0 = cluster.capacity()

    train = cluster.submit(Application.train("tinyllama-1.1b", reduced=True))
    serve = cluster.submit(Application.serve(
        "tinyllama-1.1b", reduced=True,
        serve=ServeOptions(max_batch=2, pool_pages=32)))
    assert train.state == "running" and serve.state == "running"
    assert cluster.capacity() != cap0      # capacity actually consumed

    out = train.run(steps=3)
    assert out["steps"] == 3 and np.isfinite(out["loss_last"])

    for i in range(3):
        serve.submit_request(Request(f"r{i}", prompt_len=4, max_new_tokens=4))
    stats = serve.run(max_steps=500)
    assert stats["completed"] == 3
    assert stats["tokens_generated"] == 12

    # runtime data-component scaling (paper §5.1.2)
    assert train.scale_up(2 * GB)
    assert train.job.demand_bytes > 0
    assert train.scale_down(1 * GB) == 1 * GB

    train.release()
    serve.release()
    assert cluster.capacity() == cap0, "pod accounting must restore exactly"


def test_pending_app_drains_after_release():
    cluster = Cluster([PodState("p", 4, 16 * GB)], executor=NullExecutor())
    a = cluster.submit(Application.synthetic("a", "train", 60 * GB))
    b = cluster.submit(Application.synthetic("b", "train", 60 * GB))
    assert a.state == "running" and b.state == "pending"
    a.release()
    assert b.state == "running"
    b.release()
    assert cluster.capacity()["p"]["free_bytes"] == 64 * GB


def test_pending_release_cancels():
    cluster = Cluster([PodState("p", 1, GB)], executor=NullExecutor())
    a = cluster.submit(Application.synthetic("a", "train", 10 * GB))
    assert a.state == "pending"
    a.release()
    assert not cluster.scheduler.pending


# ---------------------------------------------------------------------------
# sizing: history refines the initial grant (paper §9.3)
# ---------------------------------------------------------------------------

def test_history_sizing_refines_demand():
    import math
    hist = HistoryStore()
    for _ in range(30):
        hist.observe("syn", "job", "bytes", 8 * GB)
    cluster = Cluster(pods=1, history=hist, executor=NullExecutor())
    app = Application.synthetic("syn", "serve", 2 * GB)
    demand, sol = cluster.size(app)
    assert sol is not None and sol.feasible
    # the solved policy must cover the historical 8 GiB footprint within
    # one runtime scale-up (the objective may prefer small init + one
    # large discounted step over peak provisioning)
    k = math.ceil(max(8 * GB - sol.init, 0) / max(sol.step, 1e-9))
    assert k <= 1, sol


def test_history_sizing_never_shrinks_below_structural_floor():
    hist = HistoryStore()
    hist.observe("tinyllama-1.1b:train", "job", "bytes", 1.0)  # tiny history
    cluster = Cluster(pods=1, history=hist, executor=NullExecutor())
    app = Application.train("tinyllama-1.1b")
    demand, sol = cluster.size(app)
    assert demand >= app.structural_floor() > 0


def test_app_limit_caps_demand():
    from repro.core.annotations import AppLimits
    cluster = Cluster(pods=1, executor=NullExecutor())
    app = Application.synthetic("capped", "train", 100 * GB)
    app.limits = AppLimits(max_hbm_bytes=10 * GB)
    handle = cluster.submit(app)
    assert handle.job.demand_bytes == 10 * GB
    handle.release()


# ---------------------------------------------------------------------------
# reservation accounting (the leak fix)
# ---------------------------------------------------------------------------

def test_reservation_released_on_finish():
    hist = HistoryStore()
    hist.observe("app", "job", "bytes", 100 * GB)   # history peak: 100 GiB
    pods = [PodState("p", 16, 16 * GB)]             # 256 GiB capacity
    sched = GlobalScheduler(pods, hist)
    job = Job("j1", "app", "train", 10 * GB, 1)
    assert sched.submit(job) == "p"
    pod = sched.pods["p"].pod
    assert pod.reserved_bytes > 0                   # pre-marked future demand
    sched.finish(job)
    assert pod.reserved_bytes == 0, "reservation must be released on finish"
    assert pod.free_bytes == 256 * GB


def test_scale_up_consumes_reservation():
    hist = HistoryStore()
    hist.observe("app", "job", "bytes", 100 * GB)
    pods = [PodState("p", 16, 16 * GB)]
    sched = GlobalScheduler(pods, hist)
    job = Job("j1", "app", "train", 10 * GB, 1)
    sched.submit(job)
    pod = sched.pods["p"].pod
    res0 = pod.reserved_bytes
    assert sched.scale_up(job, 5 * GB)
    assert pod.reserved_bytes == res0 - 5 * GB
    sched.finish(job)
    assert pod.reserved_bytes == 0
    assert pod.free_bytes == 256 * GB


def test_finish_drain_terminates_with_unplaceable_pending_job():
    """Regression: finish() used to loop forever when a queued job could
    not be placed (submit re-appended it to the list being iterated)."""
    sched = GlobalScheduler([PodState("p", 1, 4 * GB)])
    a = Job("a", "app", "train", 3 * GB, 1)
    b = Job("b", "app", "train", 3 * GB, 1)
    c = Job("c", "app", "train", 10 * GB, 1)   # can never fit
    assert sched.submit(a) == "p"
    sched.submit(b)
    sched.submit(c)
    sched.finish(a)                             # must terminate
    assert b.state == "running"
    assert c in sched.pending and len(sched.pending) == 1


def test_scale_up_after_release_is_refused():
    """Regression: scaling a finished job raised KeyError instead of
    returning False (job.pod is not cleared on finish)."""
    sched = GlobalScheduler([PodState("p", 4, 16 * GB)])
    job = Job("j", "app", "train", 2 * GB, 1)
    sched.submit(job)
    sched.finish(job)
    assert not sched.scale_up(job, 1 * GB)
    assert sched.pods["p"].pod.free_bytes == 64 * GB


def test_multiple_train_apps_keep_separate_checkpoints(tmp_path):
    """Two train apps on one cluster must not cross-restore checkpoints."""
    ex = JaxExecutor(ckpt_dir=str(tmp_path), ckpt_every=2, resume=True)
    cluster = Cluster(pods=1, executor=ex)
    a = cluster.submit(Application.train("tinyllama-1.1b", reduced=True,
                                         name="app-a"))
    b = cluster.submit(Application.train("rwkv6-7b", reduced=True,
                                         name="app-b"))
    a.run(steps=4)
    b.run(steps=2)      # different tree shape: would fail on cross-restore
    a.release()
    b.release()
    assert (tmp_path / "app-a").is_dir() and (tmp_path / "app-b").is_dir()
    # a fresh same-name submission resumes from its own namespace
    a2 = cluster.submit(Application.train("tinyllama-1.1b", reduced=True,
                                          name="app-a"))
    assert a2.cursor == 4
    a2.release()


def test_admission_prefers_unreserved_pod():
    """Reservations must steer admission: a new job lands on the pod whose
    UNRESERVED capacity fits it, not on one carrying another job's reserve."""
    hist = HistoryStore()
    hist.observe("greedy", "job", "bytes", 200 * GB)
    sched = GlobalScheduler([PodState("a", 16, 16 * GB),
                             PodState("b", 16, 16 * GB)], hist)
    a = Job("a1", "greedy", "train", 10 * GB, 1)
    sched.submit(a)                      # reserves ~190 GiB on its pod
    b = Job("b1", "other", "train", 100 * GB, 1)
    sched.submit(b)
    assert b.pod is not None and b.pod != a.pod


def test_admission_falls_back_into_reserved_space():
    """Reservations are low-priority: when no pod has unreserved room the
    job still takes reserve space rather than queueing."""
    hist = HistoryStore()
    hist.observe("greedy", "job", "bytes", 200 * GB)
    sched = GlobalScheduler([PodState("a", 16, 16 * GB)], hist)
    a = Job("a1", "greedy", "train", 10 * GB, 1)
    sched.submit(a)
    b = Job("b1", "other", "train", 100 * GB, 1)
    assert sched.submit(b) == "a"        # 246 GiB free, 56 GiB unreserved


def test_serving_preemption_and_readmission():
    """Preempted requests must be re-admittable: their decode slot is
    reclaimed (regression: slot map leaked and min() hit an empty set)."""
    cluster = Cluster(pods=1, executor=JaxExecutor())
    app = Application.serve(
        "tinyllama-1.1b", reduced=True,
        serve=ServeOptions(max_batch=4, pool_pages=8, policy="fixed",
                           cache_len=512))
    h = cluster.submit(app)
    for i in range(4):
        h.submit_request(Request(f"r{i}", prompt_len=200,
                                 max_new_tokens=80))
    stats = h.run(max_steps=5000)
    assert stats["preempted"] >= 1, "scenario must exercise preemption"
    assert stats["completed"] == 4
    h.release()


def test_repeated_jobs_do_not_leak_unreserved_capacity():
    """The original bug: reserved_bytes grew forever, starving admission."""
    hist = HistoryStore()
    hist.observe("app", "job", "bytes", 40 * GB)
    pods = [PodState("p", 16, 16 * GB)]
    sched = GlobalScheduler(pods, hist)
    pod = sched.pods["p"].pod
    for i in range(50):
        job = Job(f"j{i}", "app", "train", 10 * GB, 1)
        assert sched.submit(job) == "p"
        sched.finish(job)
    assert pod.reserved_bytes == 0
    assert pod.available_unreserved == 256 * GB


# ---------------------------------------------------------------------------
# simulation path (NullExecutor) -- same submission path as real execution
# ---------------------------------------------------------------------------

def test_trace_replay_through_runtime():
    cluster = Cluster(4, executor=NullExecutor())
    apps = [Application.synthetic(f"a{i % 8}", "serve", (1 + i % 4) * GB)
            for i in range(200)]
    arrivals = [(i * 1e-6, app, 1e-4) for i, app in enumerate(apps)]
    stats = replay_trace(cluster, arrivals)
    assert stats["placed"] == 200
    assert stats["finished"] == 200
    assert stats["still_pending"] == 0
    for pod in cluster.capacity().values():
        assert pod["running"] == 0
        assert pod["reserved_bytes"] == 0


def test_cluster_throughput_beats_paper_rack_rate():
    stats = measure_cluster_throughput(n_jobs=20_000, num_pods=8)
    assert stats["finished"] == 20_000
    assert stats["sched_ops_per_s"] > 20_000, stats


# ---------------------------------------------------------------------------
# application descriptions
# ---------------------------------------------------------------------------

def test_application_from_callable_carries_annotations():
    from repro.configs import get_config
    from repro.core import annotations as ann

    @ann.app_limit(max_chips=64)
    @ann.compute(parallelism="token", name="user_app")
    def my_app():
        return get_config("tinyllama-1.1b")

    app = Application.from_callable(my_app, kind="train")
    assert app.name == "user_app"
    assert app.limits.max_chips == 64
    assert app.resource_graph().total_flops() > 0


def test_reduced_apps_are_cpu_sized():
    app = Application.train("dbrx-132b", reduced=True)
    assert app.config.d_model == 64
    assert app.shape.global_batch == 8
    assert app.estimate_demand() < 1 * GB


def test_escalate_rebinds_plan():
    cluster = Cluster(pods=1, executor=NullExecutor())
    handle = cluster.submit(Application.train("mistral-nemo-12b"))
    remat0 = handle.plan.remat
    assert handle.escalate(measured_bytes=1 << 60)
    assert handle.plan.describe() != {} and (
        handle.plan.remat != remat0 or handle.plan.fsdp
        or handle.plan.microbatch > 1)
    handle.release()
