"""Edge-case tests for the CI bench-gate (benchmarks/check_regression.py):
missing files on either side, metrics present on one side only, exact
tolerance boundaries, smoke-flag mismatches, and --update's refusal of
full-scale artifacts.

The gate is stdlib-only and lives outside the package, so it is loaded
straight from its file path.
"""

import importlib.util
import json
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent

_spec = importlib.util.spec_from_file_location(
    "check_regression", REPO / "benchmarks" / "check_regression.py")
cr = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(cr)


def write_artifact(directory, fname, rows, smoke=True):
    """rows: {row_name: {metric: value}} -> a BENCH_*.json artifact."""
    payload = {
        "rows": [{"name": name,
                  "derived": ";".join(f"{k}={v}" for k, v in d.items()),
                  "us_per_call": 7.0}
                 for name, d in rows.items()],
        "extra": {} if smoke is None else {"smoke": smoke},
    }
    directory.mkdir(parents=True, exist_ok=True)
    (directory / fname).write_text(json.dumps(payload))


@pytest.fixture
def dirs(tmp_path):
    return tmp_path / "baselines", tmp_path / "current"


# ---------------------------------------------------------------------------
# unit level: rule_for / parse_derived / check_metric
# ---------------------------------------------------------------------------

def test_rule_for_classification():
    assert cr.rule_for("completed") == ("exact", 0.0, 0.0)
    assert cr.rule_for("us_per_call") is None          # wall clock
    assert cr.rule_for("prefill_us") is None
    assert cr.rule_for("pool_util")[0] == "higher_worse"
    assert cr.rule_for("ttft_ticks_p50")[0] == "higher_worse"
    assert cr.rule_for("decode_compiles") == ("higher_worse", 0.0, 1.0)
    assert cr.rule_for("kv_bytes_ratio")[0] == "lower_worse"
    assert cr.rule_for("reuse_frac")[0] == "lower_worse"
    # the zensan entries must precede the generic *_frac catch-all: the
    # taxes are higher_worse, not lower_worse
    assert cr.rule_for("zensan_off_tax_frac") == ("higher_worse", 0.0, 0.05)
    assert cr.rule_for("zensan_overhead_frac")[0] == "higher_worse"
    assert cr.rule_for("zensan_active") == ("exact", 0.0, 0.0)
    assert cr.rule_for("some_novel_metric") is None


def test_parse_derived_percent_and_garbage():
    d = cr.parse_derived("util=55%; completed=8 ;note=n/a;;broken")
    assert d == {"util": 55.0, "completed": 8.0}


def test_check_metric_exact():
    assert cr.check_metric("completed", 8.0, 8.0)[0] == "OK"
    assert cr.check_metric("completed", 8.0, 7.0)[0] == "FAIL"


def test_check_metric_tolerance_boundary():
    # decode_compiles: rel_tol 0, abs_slack 1 -> allowed delta is
    # EXACTLY 1.0; the comparison is strict (> allowed fails)
    assert cr.check_metric("decode_compiles", 3.0, 4.0)[0] == "OK"
    assert cr.check_metric("decode_compiles", 3.0, 4.5)[0] == "FAIL"
    # improvement in the worse direction's opposite never fails
    assert cr.check_metric("decode_compiles", 3.0, 1.0)[0] == "OK"
    # lower_worse mirrors: kv_bytes_ratio rel .25, slack 0 on base 4
    assert cr.check_metric("kv_bytes_ratio", 4.0, 3.0)[0] == "OK"
    assert cr.check_metric("kv_bytes_ratio", 4.0, 2.75)[0] == "FAIL"


def test_check_metric_wall_clock_is_info_only():
    assert cr.check_metric("us_per_call", 1.0, 900.0)[0] == "INFO"


# ---------------------------------------------------------------------------
# compare(): missing files and one-sided metrics
# ---------------------------------------------------------------------------

def test_compare_empty_baseline_dir(dirs, capsys):
    baselines, current = dirs
    baselines.mkdir()
    assert cr.compare(str(baselines), str(current)) == 1
    assert "no baselines" in capsys.readouterr().err


def test_compare_missing_current_artifact(dirs, capsys):
    baselines, current = dirs
    write_artifact(baselines, "BENCH_x.json", {"row": {"completed": 8}})
    current.mkdir()
    assert cr.compare(str(baselines), str(current)) == 1
    assert "MISSING current artifact" in capsys.readouterr().out


def test_compare_gated_metric_disappeared_fails(dirs, capsys):
    baselines, current = dirs
    write_artifact(baselines, "BENCH_x.json",
                   {"row": {"completed": 8, "pool_util": 0.5}})
    write_artifact(current, "BENCH_x.json", {"row": {"completed": 8}})
    assert cr.compare(str(baselines), str(current)) == 1
    assert "gated metric disappeared" in capsys.readouterr().out


def test_compare_info_metric_disappeared_is_ignored(dirs):
    baselines, current = dirs
    write_artifact(baselines, "BENCH_x.json",
                   {"row": {"completed": 8, "prefill_us": 120.0}})
    write_artifact(current, "BENCH_x.json", {"row": {"completed": 8}})
    assert cr.compare(str(baselines), str(current)) == 0


def test_compare_metric_only_in_current_is_ignored(dirs):
    """New metrics appear before their baseline is refreshed; the gate
    only diffs what the baseline records."""
    baselines, current = dirs
    write_artifact(baselines, "BENCH_x.json", {"row": {"completed": 8}})
    write_artifact(current, "BENCH_x.json",
                   {"row": {"completed": 8, "pool_util": 0.9}})
    assert cr.compare(str(baselines), str(current)) == 0


def test_compare_row_missing_from_current(dirs, capsys):
    baselines, current = dirs
    write_artifact(baselines, "BENCH_x.json",
                   {"a": {"completed": 8}, "b": {"completed": 4}})
    write_artifact(current, "BENCH_x.json", {"a": {"completed": 8}})
    assert cr.compare(str(baselines), str(current)) == 1
    assert "row missing from current run" in capsys.readouterr().out


def test_compare_smoke_flag_mismatch_fails(dirs, capsys):
    baselines, current = dirs
    write_artifact(baselines, "BENCH_x.json", {"row": {"completed": 8}},
                   smoke=True)
    write_artifact(current, "BENCH_x.json", {"row": {"completed": 8}},
                   smoke=False)
    assert cr.compare(str(baselines), str(current)) == 1
    assert "smoke flag mismatch" in capsys.readouterr().out


def test_compare_clean_pass(dirs):
    baselines, current = dirs
    rows = {"row": {"completed": 8, "pool_util": 0.5,
                    "decode_compiles": 3}}
    write_artifact(baselines, "BENCH_x.json", rows)
    write_artifact(current, "BENCH_x.json", rows)
    assert cr.compare(str(baselines), str(current)) == 0


# ---------------------------------------------------------------------------
# update(): baseline refresh discipline
# ---------------------------------------------------------------------------

def test_update_refuses_full_scale_artifacts(dirs, capsys):
    baselines, current = dirs
    write_artifact(baselines, "BENCH_x.json", {"row": {"completed": 8}})
    before = (baselines / "BENCH_x.json").read_text()
    write_artifact(current, "BENCH_x.json", {"row": {"completed": 99}},
                   smoke=False)
    assert cr.update(str(baselines), str(current)) == 1
    assert "REFUSED" in capsys.readouterr().err
    assert (baselines / "BENCH_x.json").read_text() == before


def test_update_copies_smoke_artifacts(dirs):
    baselines, current = dirs
    write_artifact(current, "BENCH_x.json", {"row": {"completed": 8}},
                   smoke=True)
    assert cr.update(str(baselines), str(current)) == 0
    assert json.loads((baselines / "BENCH_x.json").read_text()) \
        == json.loads((current / "BENCH_x.json").read_text())


def test_update_with_no_artifacts_fails(dirs, capsys):
    baselines, current = dirs
    current.mkdir()
    assert cr.update(str(baselines), str(current)) == 1
    assert "no BENCH_" in capsys.readouterr().err
