"""Physically shared KV: view-local page-id remap + same-shape tenants
aliasing one device page-array set (KVArrayStore).

Covers the aliasing acceptance criteria: same-model tenants share ONE
physical allocation with token-exact parity to private arrays, mismatched
shapes fall back to their own store, quota shrink / preemption move
*physical* pages between apps in the same tick, the remap is an isolation
boundary (a view cannot read a page it no longer owns), and park/unpark
snapshots only the view's pages without yanking co-tenants' arrays.
"""

import pytest

from repro.core.history import HistoryStore
from repro.runtime import Application, Cluster, JaxExecutor, ServeOptions
from repro.serving.engine import ServingEngine
from repro.serving.kv_cache import PAGE_SIZE, Request
from repro.serving.tenancy import SharedPagePool


# ---------------------------------------------------------------------------
# unit level: the remap itself (no jax, no model)
# ---------------------------------------------------------------------------

def test_poolview_remap_isolation():
    """Requests hold view-local ids; physical ids come from the shared
    free list; translating an id the view no longer owns raises; freed
    physical pages become grantable to the co-tenant."""
    shared = SharedPagePool(8)
    a = shared.view("a", policy="fixed", fixed_init_pages=1)
    b = shared.view("b", policy="fixed", fixed_init_pages=1)
    ra = Request("ra", PAGE_SIZE * 2 - 4, 4)          # 2 pages
    assert a.try_admit(ra)
    ids = list(ra.pages)
    phys = a.to_physical(ids)
    assert len(set(phys)) == 2
    assert set(phys).isdisjoint(shared.free), \
        "held physical ids must not be on the shared free list"
    a.release(ra)
    with pytest.raises(KeyError, match="does not own"):
        a.to_physical(ids)
    rb = Request("rb", PAGE_SIZE * 2 - 4, 4)
    assert b.try_admit(rb)
    assert set(b.to_physical(rb.pages)) & set(phys), \
        "freed physical pages must be grantable to the co-tenant"
    # view-local ids are recycled, not leaked upward forever
    rc = Request("rc", PAGE_SIZE - 4, 4)
    assert a.try_admit(rc)
    assert set(rc.pages) <= set(ids), "freed view-local ids are recycled"


def test_resize_quota_shrink_moves_physical_pages_to_cotenant():
    """Satellite: shrink-below-usage on an aliased view drains *physical*
    pages -- the freed ids are grantable to the co-tenant in the same
    tick, and the shrunk view can no longer read them."""
    shared = SharedPagePool(4)
    a = shared.view("a", policy="fixed", fixed_init_pages=1,
                    fixed_step_pages=1)
    b = shared.view("b", policy="fixed", fixed_init_pages=1,
                    fixed_step_pages=1)
    ea = ServingEngine(a, max_batch=4)
    eb = ServingEngine(b, max_batch=4)
    for i in range(2):                                # 2 pages each
        ea.submit(Request(f"a{i}", PAGE_SIZE * 2 - 4, 8))
    ea.step()
    assert a.used == 4 and len(shared.free) == 0
    held = {r.req_id: (list(r.pages), a.to_physical(r.pages))
            for r in ea.running}
    preempted = a.resize_quota(2)
    assert preempted == 1 and a.used == 2
    victim = next(r for r in list(ea.queue) if r.state == "queued")
    old_ids, old_phys = held[victim.req_id]
    assert sorted(shared.free) == sorted(old_phys), \
        "the drained pages must be the victim's physical ids"
    with pytest.raises(KeyError, match="does not own"):
        a.to_physical(old_ids)
    # same tick: the co-tenant's grant is served from the freed ids
    eb.submit(Request("big", PAGE_SIZE * 2 - 4, 8))
    eb.step()
    assert len(eb.running) == 1
    got = set(b.to_physical(eb.running[0].pages))
    assert got == set(old_phys)
    # combined accounting still exact
    assert a.used + b.used == shared.used_pages


def test_reclaim_returns_physical_ids():
    """Park support: reclaim translates to physical ids BEFORE freeing,
    so the parked KV can be gathered off the (shared) device arrays."""
    shared = SharedPagePool(8)
    a = shared.view("a", policy="fixed", fixed_init_pages=1)
    r = Request("r", PAGE_SIZE * 2 - 4, 4)
    assert a.try_admit(r)
    phys_before = a.to_physical(r.pages)
    g, l = a.reclaim(r)
    assert g == phys_before and l == []
    assert r.pages == [] and r.state == "parked"
    assert sorted(shared.free) == list(range(8))


# ---------------------------------------------------------------------------
# integration: real paged runners aliasing one device array set
# ---------------------------------------------------------------------------

def _submit(h, reqs):
    out = []
    for rid, prompt, gen in reqs:
        r = Request(rid, prompt, gen)
        h.submit_request(r)
        out.append(r)
    return out


def _drive(handles, max_steps=8000):
    alive = set(range(len(handles)))
    steps = 0
    while alive and steps < max_steps:
        for t in list(alive):
            if not handles[t].step()["alive"]:
                alive.discard(t)
        steps += 1
    assert not alive, "tenants did not drain"


def test_mixed_pod_aliasing_acceptance():
    """The tenancy acceptance scenario with physical aliasing: two
    same-model tenants alias ONE device array set, a same-model tenant
    with ``alias_kv=False`` keeps private arrays, and a different-model
    tenant (mismatched KV shape) falls back to its own store -- all
    token-exact: tenants given identical request ids produce identical
    tokens regardless of whose arrays they write."""
    cluster = Cluster(pods=1, history=HistoryStore(),
                      executor=JaxExecutor(seed=0), pool_pages=64)
    mk = lambda name, arch, **o: cluster.submit(Application.serve(
        arch, reduced=True, name=name,
        serve=ServeOptions(max_batch=4, backend="paged",
                           policy="fixed", **o)))
    a = mk("alias-a", "tinyllama-1.1b")
    b = mk("alias-b", "tinyllama-1.1b")
    c = mk("private-c", "tinyllama-1.1b", alias_kv=False)
    d = mk("other-d", "gemma3-12b")

    shared = cluster.pod_pool("pod0")
    assert a.runner.store is b.runner.store, "same shape must alias"
    assert c.runner.store is not a.runner.store, "alias_kv=False is private"
    assert d.runner.store is not a.runner.store, "shape mismatch no alias"
    assert a.runner.shared_kv and b.runner.shared_kv
    assert not c.runner.shared_kv
    # pod registry: the aliased tinyllama store + gemma3's own; C's
    # private store is runner-held, not pod-registered
    assert len(shared.kv_stores) == 2

    same = [("r0", 200, 6), ("r1", 64, 6)]
    ra, rb, rc = _submit(a, same), _submit(b, same), _submit(c, same)
    rd = _submit(d, [("d0", 200, 8), ("d1", 96, 8)])
    _drive([a, b, c, d])

    toks = lambda rs: [tuple(r.output_tokens) for r in rs]
    assert toks(ra) == toks(rb) == toks(rc), \
        "aliased tenants must be token-exact vs private arrays"
    assert all(r.output_tokens is not None for r in rd)

    sa = a.serving_stats()
    assert sa["kv_aliased"] is True
    assert sa["kv_device_bytes"] == b.serving_stats()["kv_device_bytes"]
    assert sa["completed"] == 2
    # pod-level live bytes count the aliased store ONCE
    assert (sa["shared_pool"]["kv_device_bytes"]
            == a.runner.store.device_bytes() + d.runner.store.device_bytes())
    for h in (a, b, c, d):
        h.release()
    assert not shared.kv_stores, "last tenant takes the store's HBM with it"


def test_park_unpark_aliased_keeps_cotenant_arrays():
    """Parking one aliased tenant must snapshot only ITS pages: the
    shared device arrays stay (the co-tenant is decoding through them),
    the parked tenant's physical pages return to the shared free list,
    and unpark restores token-identical decoding."""
    def run(park_mid):
        cluster = Cluster(pods=1, history=HistoryStore(),
                          executor=JaxExecutor(seed=0), pool_pages=16)
        t0 = cluster.submit(Application.serve(
            "tinyllama-1.1b", reduced=True, name="t0",
            serve=ServeOptions(max_batch=2, backend="paged",
                               policy="fixed")))
        t1 = cluster.submit(Application.serve(
            "tinyllama-1.1b", reduced=True, name="t1",
            serve=ServeOptions(max_batch=2, backend="paged",
                               policy="fixed")))
        r0 = _submit(t0, [("a", 200, 24), ("b", 64, 24)])
        r1 = _submit(t1, [("c", 200, 24), ("d", 64, 24)])
        for _ in range(3):
            t0.step()
            t1.step()
        if park_mid:
            shared = cluster.pod_pool("pod0")
            used_before = shared.used_pages
            receipt = t0.park()
            assert receipt["kv_arrays_dropped"] is False, \
                "co-tenant still aliases the arrays"
            assert t0.runner.store.k_pages is not None
            assert shared.used_pages < used_before, \
                "parked tenant's physical pages must be freed"
            for _ in range(6):       # co-tenant decodes (and may reuse
                t1.step()            # the freed physical pages) meanwhile
            t0.unpark()
        _drive([t0, t1])
        for h in (t0, t1):
            assert h.serving_stats()["completed"] == 2
        out = [tuple(r.output_tokens) for r in r0 + r1]
        t0.release()
        t1.release()
        return out

    assert run(park_mid=True) == run(park_mid=False), \
        "park/unpark must be token-identical under aliasing"


def test_all_parked_aliased_tenants_drop_arrays():
    """A parked co-tenant must not keep the shared arrays alive: when the
    LAST active tenant parks (or releases while the rest are parked) the
    pod pays zero KV HBM, and any unpark revives the arrays."""
    cluster = Cluster(pods=1, history=HistoryStore(),
                      executor=JaxExecutor(seed=0), pool_pages=16)
    a = cluster.submit(Application.serve(
        "tinyllama-1.1b", reduced=True, name="a",
        serve=ServeOptions(max_batch=2, backend="paged",
                           policy="fixed")))
    b = cluster.submit(Application.serve(
        "tinyllama-1.1b", reduced=True, name="b",
        serve=ServeOptions(max_batch=2, backend="paged",
                           policy="fixed")))
    ra = _submit(a, [("a0", 64, 12)])
    rb = _submit(b, [("b0", 64, 12)])
    for _ in range(2):
        a.step()
        b.step()
    store = a.runner.store
    assert a.park()["kv_arrays_dropped"] is False   # b still active
    assert b.park()["kv_arrays_dropped"] is True    # last active tenant
    assert store.device_bytes() == 0
    a.unpark()                                      # revives the arrays
    assert store.k_pages is not None
    b.unpark()
    _drive([a, b])
    assert len(ra[0].output_tokens) == len(rb[0].output_tokens) == 13
    # release while the co-tenant is parked: arrays drop again
    b.park()
    a.release()
    assert store.device_bytes() == 0, \
        "a parked sole survivor must not pin the store's HBM"
    b.unpark()
    b.release()
    assert not cluster.pod_pool("pod0").kv_stores


def test_sole_aliased_tenant_park_drops_arrays():
    """With no co-tenant left, parking the last aliasing tenant DOES
    drop the device arrays (the PR 3 reclamation) and unpark revives
    them."""
    cluster = Cluster(pods=1, history=HistoryStore(),
                      executor=JaxExecutor(seed=0), pool_pages=16)
    h = cluster.submit(Application.serve(
        "tinyllama-1.1b", reduced=True, name="solo",
        serve=ServeOptions(max_batch=2, backend="paged",
                           policy="fixed")))
    reqs = _submit(h, [("a", 200, 16)])
    for _ in range(3):
        h.step()
    store = h.runner.store
    receipt = h.park()
    assert receipt["kv_arrays_dropped"] is True
    assert store.device_bytes() == 0 and store.k_pages is None
    h.unpark()
    assert store.k_pages is not None
    _drive([h])
    assert h.serving_stats()["completed"] == 1
    assert len(reqs[0].output_tokens) == 17
    h.release()
