"""Multi-device tests: run in a subprocess with 8 forced host devices so
the main pytest process keeps its single-device view.

Covers: sharded train step executes + matches single-device numerics,
seq-sharded decode (shard_map flash-decode) equals unsharded decode,
shard_map MoE equals local MoE, and elastic checkpoint restore onto a
different mesh."""

import os
import subprocess
import sys
import textwrap


SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


def run_sub(code: str, timeout=420):
    env = dict(os.environ,
               PYTHONPATH=SRC,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               TF_CPP_MIN_LOG_LEVEL="3")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout,
                       env=env)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr[-4000:]}"
    return r.stdout


COMMON = """
import sys; sys.path.insert(0, {src!r})
import jax, jax.numpy as jnp, numpy as np, dataclasses
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_config
from repro.configs.reduced import reduced_config as reduced
from repro.launch.mesh import _make_mesh
from repro.models import build_model, ImplConfig

mesh = _make_mesh((2, 4), ("data", "model"))
"""


def test_seqshard_decode_equals_unsharded():
    run_sub(COMMON.format(src=SRC) + """
cfg = reduced(get_config("mistral-nemo-12b"))
B, S, CL = 4, 12, 32
rng = jax.random.PRNGKey(0)
toks = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)

# unsharded reference
m0 = build_model(cfg, ImplConfig(remat="none"))
params = m0.init_params(rng)
logits0, cache0 = jax.jit(lambda p, b: m0.prefill(p, b, CL))(params, {"tokens": toks})
nxt = jnp.zeros((B, 1), jnp.int32)
l0, c0 = jax.jit(m0.decode_step)(params, nxt, cache0, jnp.asarray(S, jnp.int32))

# sequence-sharded decode via shard_map flash-decode
impl = ImplConfig(remat="none", decode_shard_ctx=(mesh, ("model",), ("data",)))
m1 = build_model(cfg, impl)
cache_sharding = jax.tree.map(
    lambda a: NamedSharding(mesh, P(None, "data", None, "model", None)), cache0)
with mesh:
    cache_sh = jax.tree.map(lambda a, s: jax.device_put(a, s), cache0, cache_sharding)
    l1, c1 = jax.jit(m1.decode_step)(params, nxt, cache_sh, jnp.asarray(S, jnp.int32))
np.testing.assert_allclose(np.asarray(l0, np.float32), np.asarray(l1, np.float32),
                           rtol=5e-2, atol=5e-2)
# cache contents must match too (the new token row written on the owner shard)
for a, b in zip(jax.tree.leaves(c0), jax.tree.leaves(c1)):
    np.testing.assert_allclose(np.asarray(a, np.float32), np.asarray(b, np.float32),
                               rtol=5e-2, atol=5e-2)
print("seqshard decode OK")
""")


def test_moe_shard_map_equals_local():
    run_sub(COMMON.format(src=SRC) + """
from repro.models.moe import moe_block
from repro.models.transformer import block_specs
from repro.models import layers as L
cfg = reduced(get_config("qwen2-moe-a2.7b"))
p = L.init_from_specs(jax.random.PRNGKey(0), block_specs(cfg, "moe")["moe"])
x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, cfg.d_model), jnp.bfloat16)
y0, aux0 = moe_block(p, x, cfg)                      # local reference
with mesh:
    y1, aux1 = jax.jit(lambda p, x: moe_block(p, x, cfg,
        shard_ctx=(mesh, "model", ("data",))))(p, x)
np.testing.assert_allclose(np.asarray(y0, np.float32), np.asarray(y1, np.float32),
                           rtol=6e-2, atol=6e-2)
assert abs(float(aux0) - float(aux1)) < 4e-2, (float(aux0), float(aux1))
print("moe shard_map OK")
""")


def test_sharded_train_step_matches_single_device():
    run_sub(COMMON.format(src=SRC) + """
from repro.training import optimizer as opt
from repro.training.train_step import make_train_step
from repro.core.materializer import Plan, MeshSpec
from repro.sharding import planner

cfg = reduced(get_config("tinyllama-1.1b"))
model = build_model(cfg, ImplConfig(remat="none"))
rng = jax.random.PRNGKey(0)
params = model.init_params(rng)
opt_state = opt.init_opt_state(params)
batch = {"tokens": jax.random.randint(rng, (8, 16), 0, cfg.vocab_size),
         "labels": jax.random.randint(rng, (8, 16), 0, cfg.vocab_size)}

spec = MeshSpec("test", (2, 4), ("data", "model"))
plan = Plan("t", "train_4k", spec, batch_axes=("data",), tp=True,
            zero=True, remat="none", microbatch=1)
step = make_train_step(model, plan)

# single device
p1, o1, m1 = jax.jit(step)(params, opt_state, batch)

# sharded
specs = model.param_specs()
psh = planner.to_named(planner.param_specs_tree(plan, cfg, specs), mesh)
osh = {"m": planner.to_named(planner.opt_state_specs_tree(plan, cfg, specs), mesh),
       "v": planner.to_named(planner.opt_state_specs_tree(plan, cfg, specs), mesh),
       "master": planner.to_named(planner.opt_state_specs_tree(plan, cfg, specs), mesh),
       "count": NamedSharding(mesh, P())}
bsh = {k: NamedSharding(mesh, P("data", None)) for k in batch}
with mesh:
    p2, o2, m2 = jax.jit(step, in_shardings=(psh, osh, bsh),
                         out_shardings=(psh, osh, None))(params, opt_state, batch)
assert abs(float(m1["loss"]) - float(m2["loss"])) < 5e-2, (float(m1["loss"]), float(m2["loss"]))
for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
    np.testing.assert_allclose(np.asarray(a, np.float32), np.asarray(b, np.float32),
                               rtol=8e-2, atol=8e-2)
print("sharded train OK", float(m1["loss"]), float(m2["loss"]))
""")


def test_elastic_restore_onto_different_mesh():
    run_sub(COMMON.format(src=SRC) + """
import tempfile, os
from repro.checkpoint.checkpointer import save_checkpoint, restore_checkpoint
from repro.sharding import planner
from repro.core.materializer import Plan, MeshSpec

cfg = reduced(get_config("tinyllama-1.1b"))
model = build_model(cfg, ImplConfig(remat="none"))
params = model.init_params(jax.random.PRNGKey(0))

mesh_a = _make_mesh((2, 4), ("data", "model"))
mesh_b = _make_mesh((4, 2), ("data", "model"))
spec_a = MeshSpec("a", (2, 4), ("data", "model"))
spec_b = MeshSpec("b", (4, 2), ("data", "model"))
plan_a = Plan("t", "train_4k", spec_a, batch_axes=("data",), tp=True)
plan_b = Plan("t", "train_4k", spec_b, batch_axes=("data",), tp=True)
specs = model.param_specs()
sh_a = planner.to_named(planner.param_specs_tree(plan_a, cfg, specs), mesh_a)
sh_b = planner.to_named(planner.param_specs_tree(plan_b, cfg, specs), mesh_b)
params_a = jax.tree.map(lambda x, s: jax.device_put(x, s), params, sh_a)

d = tempfile.mkdtemp()
save_checkpoint(d, 5, params_a, extra={"mesh": "a"})
restored, extra, step = restore_checkpoint(d, 5, params, shardings=sh_b)
for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
# verify placement follows mesh_b
leaf = jax.tree.leaves(restored)[0]
assert leaf.sharding.mesh.shape["data"] == 4
print("elastic restore OK")
""")
