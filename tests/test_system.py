"""End-to-end behaviour tests for the paper's system.

The headline claims, scaled to CPU-test size:
  1. training LEARNS (loss decreases on structured synthetic data);
  2. adaptive materialization produces DIFFERENT plans for different
     invocations of the same app (the paper's Fig. 1/6 behaviour);
  3. history-based sizing beats fixed sizing and peak-provisioning on the
     utilization/performance trade-off (paper Fig. 22);
  4. the engine + pool + sizing close the loop end-to-end.
"""


import jax
import jax.numpy as jnp
import numpy as np

from conftest import reduced_config
from repro.configs import SHAPES, get_config
from repro.core.history import HistoryStore
from repro.core.materializer import MULTI_POD, SINGLE_POD, Plan, materialize
from repro.core.sizing import (fixed_sizing, peak_sizing, simulate_policy,
                               solve_init_step)
from repro.data.pipeline import DataConfig, SyntheticLM, make_loader
from repro.models import ImplConfig, build_model
from repro.training import optimizer as opt
from repro.training.train_step import make_train_step


def test_training_learns(rng):
    """30 steps on structured data must reduce loss by >20%."""
    cfg = reduced_config(get_config("tinyllama-1.1b"),
                         d_model=128, num_layers=2, d_ff=256)
    model = build_model(cfg, ImplConfig(remat="none"))
    params = model.init_params(rng)
    opt_state = opt.init_opt_state(params)
    plan = Plan("t", "train_4k", SINGLE_POD, microbatch=1, remat="none")
    ocfg = opt.OptimizerConfig(peak_lr=3e-3, warmup_steps=5, decay_steps=100)
    step = jax.jit(make_train_step(model, plan, ocfg))
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=8)
    data = SyntheticLM(dcfg)
    losses = []
    for i in range(30):
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(i).items()}
        params, opt_state, m = step(params, opt_state, batch)
        losses.append(float(m["loss"]))
    first = np.mean(losses[:5])
    last = np.mean(losses[-5:])
    assert last < 0.8 * first, f"no learning: {first:.3f} -> {last:.3f}"


def test_microbatched_step_matches_full_batch(rng):
    """Gradient accumulation over microbatches == one full-batch step."""
    cfg = reduced_config(get_config("tinyllama-1.1b"))
    model = build_model(cfg, ImplConfig(remat="none"))
    params = model.init_params(rng)
    batch = {"tokens": jax.random.randint(rng, (8, 16), 0, cfg.vocab_size),
             "labels": jax.random.randint(rng, (8, 16), 0, cfg.vocab_size)}
    p1 = Plan("t", "train_4k", SINGLE_POD, microbatch=1, remat="none")
    p4 = Plan("t", "train_4k", SINGLE_POD, microbatch=4, remat="none")
    o0 = opt.init_opt_state(params)
    pa, _, ma = jax.jit(make_train_step(model, p1))(params, o0, batch)
    o0b = opt.init_opt_state(params)
    pb, _, mb = jax.jit(make_train_step(model, p4))(params, o0b, batch)
    assert abs(float(ma["loss"]) - float(mb["loss"])) < 5e-2
    for a, b in zip(jax.tree.leaves(pa), jax.tree.leaves(pb)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=5e-2, atol=5e-2)


def test_adaptive_plans_differ_across_invocations():
    """Same platform, different invocations -> different materializations."""
    tiny = get_config("tinyllama-1.1b")
    big = get_config("dbrx-132b")
    p_tiny = materialize(tiny, SHAPES["train_4k"], SINGLE_POD)
    p_big = materialize(big, SHAPES["train_4k"], SINGLE_POD)
    assert not p_tiny.tp and p_big.tp
    assert p_big.fsdp and not p_tiny.fsdp
    p_dec = materialize(big, SHAPES["decode_32k"], SINGLE_POD)
    assert p_dec.kv_shard_seq or p_dec.kv_shard_heads
    p_mp = materialize(big, SHAPES["train_4k"], MULTI_POD)
    assert "pod" in p_mp.batch_axes


def test_history_sizing_beats_fixed_and_peak():
    """Paper Fig. 22: history-based sizing vs fixed vs peak-provision."""
    rng = np.random.default_rng(0)
    usage = np.exp(rng.normal(3.0, 1.0, size=600)).clip(1, 400)
    hist = [(float(v), 1.0) for v in usage]
    h_sol = solve_init_step(hist, cost_factor=0.3, waste_threshold=0.5)
    f_sol = fixed_sizing(4.0, 1.0)
    p_sol = peak_sizing(hist)
    sim_h = simulate_policy(usage, h_sol)
    sim_f = simulate_policy(usage, f_sol)
    sim_p = simulate_policy(usage, p_sol)
    assert sim_h["mean_utilization"] > sim_p["mean_utilization"] + 0.1
    assert sim_h["mean_scaleups"] < sim_f["mean_scaleups"]
    assert sim_p["mean_time"] <= sim_h["mean_time"] <= sim_f["mean_time"] + 1e-9


def test_engine_history_feedback_loop():
    """Serving requests feed the history store; pool sizing adapts."""
    from repro.serving.engine import ServingEngine
    from repro.serving.kv_cache import PagePool, Request
    hist = HistoryStore()
    pool = PagePool(256, history=hist, policy="history")
    eng = ServingEngine(pool, max_batch=8, history=hist)
    init_before = pool.sizing().init
    for i in range(40):
        eng.submit(Request(f"r{i}", prompt_len=700, max_new_tokens=16))
    eng.run_to_completion(max_steps=5000)
    pool._sizing = None  # force re-solve from accumulated history
    sz = pool.sizing()
    assert sz.init >= init_before
    # adapted policy must cover a 7-page request within <=2 scale-ups
    import math
    k = math.ceil(max(7 - sz.init, 0) / max(sz.step, 1e-9))
    assert k <= 2, f"sizing did not adapt: {sz}"


def test_prefetch_loader_delivers_in_order():
    dcfg = DataConfig(vocab_size=64, seq_len=8, global_batch=2)
    loader = make_loader(dcfg, start_step=3, prefetch=2)
    ref = SyntheticLM(dcfg)
    for i in range(3, 6):
        got = next(loader)
        np.testing.assert_array_equal(got["tokens"], ref.batch_at(i)["tokens"])
    loader.close()


def test_annotations_register_components():
    from repro.core import annotations as ann
    ann.reset_annotations()

    @ann.app_limit(max_chips=16)
    @ann.compute(parallelism="token")
    def my_block(x):
        return x * 2

    @ann.data("my_buffer", input_dependent=True)
    def alloc(n):
        return jnp.zeros((n,))

    assert my_block(jnp.ones(3))[0] == 2
    kinds = {c["kind"] for c in ann.collected_annotations()}
    assert kinds == {"compute", "data"}
    assert ann.current_app_limits().max_chips == 16


def test_grad_compression_roundtrip():
    from repro.training.train_step import _compress_int8
    x = jnp.asarray(np.random.default_rng(0).normal(0, 0.02, (64, 64)),
                    jnp.float32)
    y = _compress_int8(x)
    err = float(jnp.max(jnp.abs(x - y)))
    assert err <= float(jnp.max(jnp.abs(x))) / 127 + 1e-6
