"""Checkpoint + recovery: atomic commit, async writer, graph-cut replay
determinism, straggler watchdog, failure injection."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import reduced_config
from repro.checkpoint.checkpointer import (AsyncCheckpointer, latest_step,
                                           restore_checkpoint,
                                           save_checkpoint)
from repro.checkpoint.recovery import (CutTracker, ElasticPolicy,
                                       FailureInjector, RecoveryPoint,
                                       StragglerWatchdog, elastic_replan)
from repro.configs import SHAPES, get_config
from repro.core.materializer import MULTI_POD, SINGLE_POD
from repro.data.pipeline import DataConfig, SyntheticLM


def _tree(rng):
    return {
        "a": jax.random.normal(rng, (8, 16), jnp.float32),
        "nested": {"b": jnp.arange(10, dtype=jnp.int32),
                   "c": jax.random.normal(rng, (4,), jnp.bfloat16)},
    }


def test_roundtrip(tmp_path, rng):
    tree = _tree(rng)
    path = save_checkpoint(str(tmp_path), 7, tree, extra={"cursor": 123})
    assert os.path.basename(path) == "step_00000007"
    restored, extra, step = restore_checkpoint(str(tmp_path), None, tree)
    assert step == 7 and extra["cursor"] == 123
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_atomic_commit_ignores_tmp(tmp_path, rng):
    tree = _tree(rng)
    save_checkpoint(str(tmp_path), 1, tree)
    # simulate a crash mid-write at step 2
    os.makedirs(tmp_path / "step_00000002.tmp")
    assert latest_step(str(tmp_path)) == 1


def test_restore_validates_shapes(tmp_path, rng):
    tree = _tree(rng)
    save_checkpoint(str(tmp_path), 1, tree)
    bad = dict(tree, a=jnp.zeros((9, 16)))
    with pytest.raises(ValueError):
        restore_checkpoint(str(tmp_path), 1, bad)


def test_async_checkpointer_and_gc(tmp_path, rng):
    ck = AsyncCheckpointer(str(tmp_path), keep=2)
    tree = _tree(rng)
    for s in (1, 2, 3, 4):
        ck.save(s, tree)
    ck.wait()
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(tmp_path)
                   if d.startswith("step_"))
    assert steps == [3, 4]


def test_cut_tracker_replay_span():
    ct = CutTracker()
    ct.record(RecoveryPoint(10, "p", 10, "single_pod"))
    ct.record(RecoveryPoint(20, "p", 20, "single_pod"))
    start, lost = ct.replay_span(27)
    assert start == 20 and lost == 7


def test_data_replay_determinism():
    cfg = DataConfig(vocab_size=128, seq_len=16, global_batch=4)
    src = SyntheticLM(cfg)
    b1 = src.batch_at(5)
    b2 = src.batch_at(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = src.batch_at(6)
    assert not np.array_equal(b1["tokens"], b3["tokens"])


def test_recovery_resumes_identically(tmp_path, rng):
    """Train 6 steps; crash at 4 (after checkpoint at 3); recover from the
    cut; final params must equal the uninterrupted run bit-for-bit."""
    from repro.models import ImplConfig, build_model
    from repro.training import optimizer as opt
    from repro.training.train_step import make_train_step
    from repro.core.materializer import Plan

    cfg = reduced_config(get_config("tinyllama-1.1b"))
    model = build_model(cfg, ImplConfig(remat="none"))
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=16, global_batch=4)
    data = SyntheticLM(dcfg)
    plan = Plan("t", "train_4k", SINGLE_POD, microbatch=1, remat="none")
    step = jax.jit(make_train_step(model, plan))

    def run(n, params, opt_state, start=0):
        for i in range(start, n):
            batch = {k: jnp.asarray(v) for k, v in data.batch_at(i).items()}
            params, opt_state, _ = step(params, opt_state, batch)
        return params, opt_state

    p0 = model.init_params(rng)
    o0 = opt.init_opt_state(p0)

    # uninterrupted
    p_ref, _ = run(6, p0, o0)

    # crash-and-recover
    inj = FailureInjector(fail_at_steps=(4,))
    p, o = p0, o0
    try:
        for i in range(6):
            inj.maybe_fail(i)
            batch = {k: jnp.asarray(v) for k, v in data.batch_at(i).items()}
            p, o, _ = step(p, o, batch)
            if i == 2:  # cut: checkpoint after step index 2 (3 steps done)
                save_checkpoint(str(tmp_path), i + 1, {"p": p, "o": o},
                                extra={"cursor": i + 1})
    except RuntimeError:
        restored, extra, _ = restore_checkpoint(
            str(tmp_path), None, {"p": p0, "o": o0})
        p, o = restored["p"], restored["o"]
        p, o = run(6, p, o, start=extra["cursor"])

    for a, b in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_straggler_watchdog():
    wd = StragglerWatchdog(slack=2.0, warmup=5)
    for i in range(20):
        assert not wd.observe(i, 0.1)
    assert wd.observe(99, 10.0)
    assert wd.flags and wd.flags[0][0] == 99


def test_elastic_policy_and_replan():
    pol = ElasticPolicy([MULTI_POD, SINGLE_POD])
    assert pol.current_mesh().name == "multi_pod"
    nxt = pol.shrink()
    assert nxt.name == "single_pod"
    assert pol.shrink() is None
    cfg = get_config("mistral-nemo-12b")
    plan = elastic_replan(cfg, SHAPES["train_4k"], nxt)
    assert plan.mesh.name == "single_pod"
    assert plan.notes
