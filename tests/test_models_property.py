"""Model-level invariants: attention impl equivalence, masking semantics,
MoE sharded-vs-local equivalence, ring-buffer windows."""


import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from conftest import reduced_config
from repro.configs import get_config
from repro.models.attention import gqa_decode_sdpa, sdpa

RNG = np.random.default_rng(3)


def ra(*shape, scale=1.0, dtype=jnp.float32):
    return jnp.asarray(RNG.standard_normal(shape) * scale, dtype)


@settings(max_examples=12, deadline=None)
@given(st.sampled_from([64, 128, 192]), st.sampled_from([1, 2, 4]),
       st.sampled_from([0, 24]), st.booleans())
def test_chunked_equals_naive(s, group, window, causal):
    b, kvh, hd = 2, 2, 16
    h = kvh * group
    q, k, v = ra(b, s, h, hd), ra(b, s, kvh, hd), ra(b, s, kvh, hd)
    if not causal and window:
        window = 0
    o_naive = sdpa(q, k, v, causal=causal, window=window, impl="naive")
    o_chunk = sdpa(q, k, v, causal=causal, window=window, impl="chunked",
                   chunk=32)
    np.testing.assert_allclose(np.asarray(o_naive, np.float32),
                               np.asarray(o_chunk, np.float32),
                               rtol=2e-5, atol=2e-5)


def test_causal_mask_no_future_leak():
    """Changing future tokens must not affect past outputs."""
    b, s, h, hd = 1, 32, 2, 8
    q, k, v = ra(b, s, h, hd), ra(b, s, h, hd), ra(b, s, h, hd)
    o1 = sdpa(q, k, v, causal=True)
    k2 = k.at[:, s // 2:].set(9.0)
    v2 = v.at[:, s // 2:].set(-9.0)
    o2 = sdpa(q, k2, v2, causal=True)
    np.testing.assert_allclose(np.asarray(o1[:, : s // 2]),
                               np.asarray(o2[:, : s // 2]), rtol=1e-6)


def test_sliding_window_ignores_distant_tokens():
    b, s, h, hd, w = 1, 64, 2, 8, 8
    q, k, v = ra(b, s, h, hd), ra(b, s, h, hd), ra(b, s, h, hd)
    o1 = sdpa(q, k, v, causal=True, window=w)
    # perturb tokens more than `w` in the past of the last position
    k2 = k.at[:, : s - w - 1].set(5.0)
    v2 = v.at[:, : s - w - 1].set(5.0)
    o2 = sdpa(q, k2, v2, causal=True, window=w)
    np.testing.assert_allclose(np.asarray(o1[:, -1]), np.asarray(o2[:, -1]),
                               rtol=1e-6)


def test_gqa_decode_sdpa_matches_full():
    b, h, kvh, s, hd = 2, 8, 2, 64, 16
    q = ra(b, 1, h, hd)
    k = ra(b, kvh, s, hd)   # (B, KV, S, hd) cache layout
    v = ra(b, kvh, s, hd)
    valid = jnp.arange(s) < 40
    o = gqa_decode_sdpa(q, k, v, valid)
    # reference: naive sdpa over the (B, S, KV, hd) layout
    o_ref = sdpa(q, k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3),
                 causal=False, k_valid=valid)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                               rtol=2e-5, atol=2e-5)


def test_decode_ring_buffer_equals_full_cache():
    """Sliding-window decode via ring buffer == full-cache windowed attn."""
    from repro.models import ImplConfig, build_model
    cfg = reduced_config(get_config("gemma3-12b"))
    # single local-attn layer for surgical comparison
    cfg = cfg.scaled(pattern=("attn_local",), num_layers=1, sliding_window=8)
    model = build_model(cfg, ImplConfig(remat="none"))
    params = model.init_params(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 21), 0,
                              cfg.vocab_size)
    # path A: prefill over first 20, decode token 20
    batch = {"tokens": toks[:, :20]}
    _, cache = jax.jit(lambda p, b: model.prefill(p, b, 64))(params, batch)
    la, _ = jax.jit(model.decode_step)(params, toks[:, 20:21], cache,
                                       jnp.asarray(20, jnp.int32))
    # path B: full forward over 21 tokens
    lb, _ = jax.jit(lambda p, b: model.prefill(p, b, 64))(
        params, {"tokens": toks})
    np.testing.assert_allclose(np.asarray(la[:, -1], np.float32),
                               np.asarray(lb[:, -1], np.float32),
                               rtol=0.1, atol=0.25)
    assert (np.argmax(np.asarray(la[:, -1]), -1)
            == np.argmax(np.asarray(lb[:, -1]), -1)).all()


def test_moe_local_path_deterministic_and_sparse():
    from repro.models.moe import moe_block
    cfg = reduced_config(get_config("dbrx-132b"))
    from repro.models.transformer import block_specs
    from repro.models import layers as L
    specs = block_specs(cfg, "moe")["moe"]
    params = L.init_from_specs(jax.random.PRNGKey(0), specs)
    x = ra(2, 8, cfg.d_model, dtype=jnp.bfloat16)
    y1, aux1 = moe_block(params, x, cfg)
    y2, aux2 = moe_block(params, x, cfg)
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))
    assert np.isfinite(np.asarray(y1, np.float32)).all()
    assert float(aux1) == float(aux2) and float(aux1) > 0


def test_rwkv_decode_matches_chunked_train():
    """Per-step decode recurrence == chunked train path, token by token."""
    from repro.models import rwkv6 as rw
    cfg = reduced_config(get_config("rwkv6-7b"))
    from repro.models.transformer import block_specs
    from repro.models import layers as L
    p = L.init_from_specs(jax.random.PRNGKey(0),
                          block_specs(cfg, "rwkv6")["rwkv"])
    b, s = 1, 8
    x = ra(b, s, cfg.d_model, dtype=jnp.float32).astype(jnp.bfloat16)
    y_train = rw.time_mix_train(p, x, cfg, chunk=4)
    state = rw.init_rwkv_state(cfg, b)
    outs = []
    for t in range(s):
        y, state = rw.time_mix_decode(p, x[:, t:t + 1], state, cfg)
        outs.append(y)
    y_dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_train, np.float32),
                               np.asarray(y_dec, np.float32),
                               rtol=5e-2, atol=5e-2)
