"""Scale-out data plane: RequestRouter + ReplicaSet.

Dispatch spreading, per-app fairness, token-identical replica drain
(and the dense at-least-once fallback), scale-to-zero == park, the
replica/batch autoscale dimensions, and the aggregated StatsView.
"""

import pytest

from repro import obs
from repro.core.history import HistoryStore
from repro.runtime import (Application, Cluster, JaxExecutor, NullExecutor,
                           ScalePolicy, ServeOptions)
from repro.serving.kv_cache import PAGE_SIZE, Request
from repro.serving.stats import aggregate_engine_stats


@pytest.fixture(autouse=True)
def _obs_off():
    obs.disable()
    obs.disable_metrics()
    yield
    obs.disable()
    obs.disable_metrics()


def _null_cluster(pool_pages=64):
    return Cluster(pods=1, history=HistoryStore(),
                   executor=NullExecutor(), pool_pages=pool_pages)


def _serve(cluster, name, **opts):
    return cluster.submit(Application.serve(
        "tinyllama-1.1b", reduced=True, name=name,
        serve=ServeOptions(**opts)))


def _reqs(n, prefix="r", prompt=PAGE_SIZE - 4, max_new=6):
    return [Request(f"{prefix}{i}", prompt, max_new) for i in range(n)]


# ---------------------------------------------------------------------------
# dispatch
# ---------------------------------------------------------------------------

def test_router_spreads_requests_across_replicas():
    cluster = _null_cluster()
    h = _serve(cluster, "spread", max_batch=2, replicas=3)
    for r in _reqs(12):
        h.submit_request(r)
    h.run(max_steps=1000)

    rset = h.replica_set
    assert len(rset.replicas) == 3
    # JSQ with batch headroom: nobody sits idle while others overflow
    per_replica = [r.engine.stats.admitted for r in rset.replicas]
    assert all(a > 0 for a in per_replica), per_replica
    rstats = h.serving_stats()["router"]
    assert rstats["submitted"] == 12
    assert rstats["dispatched"] == 12
    assert rstats["queue_len"] == 0
    assert aggregate_engine_stats(h).completed == 12
    h.release()


def test_router_late_binding_queues_when_full():
    """A request with no replica headroom waits at the ROUTER (where its
    depth is the scaling signal), not pinned early to an engine lane."""
    cluster = _null_cluster()
    h = _serve(cluster, "late", max_batch=2, replicas=2)
    for r in _reqs(9):
        h.submit_request(r)
    rstats = h.serving_stats()["router"]
    assert rstats["dispatched"] == 4          # 2 replicas x max_batch 2
    assert rstats["queue_len"] == 5
    h.run(max_steps=1000)
    assert aggregate_engine_stats(h).completed == 9
    h.release()


def test_router_fairness_no_starvation():
    """One heavy and one light tenant on the same pod router: the light
    tenant's requests complete in near-isolation latency because every
    app has its own queue + replicas (per-round service, no
    head-of-line blocking)."""
    cluster = _null_cluster(pool_pages=128)
    heavy = _serve(cluster, "heavy", max_batch=2)
    light = _serve(cluster, "light", max_batch=2)
    for r in _reqs(40, prefix="h"):
        heavy.submit_request(r)
    for r in _reqs(2, prefix="l"):
        light.submit_request(r)

    router = cluster.router(heavy.pod)
    assert router is cluster.router(light.pod)
    rounds = 0
    while light.engine.stats.completed < 2:
        assert router.step(), "router went idle with light reqs pending"
        rounds += 1
        assert rounds <= 25, "light tenant starved behind heavy backlog"
    # the heavy backlog is still mostly unserved: light did NOT wait on it
    assert heavy.engine.stats.completed < 40
    while router.step():
        pass
    assert heavy.engine.stats.completed == 40
    heavy.release()
    light.release()


# ---------------------------------------------------------------------------
# replica drain / failover
# ---------------------------------------------------------------------------

def _paged_tokens(replicas, drain_after=None):
    """Serve 4 requests on the paged backend; optionally drain one
    replica mid-decode.  Returns ({req_id: tokens}, receipt)."""
    cluster = Cluster(pods=1, history=HistoryStore(),
                      executor=JaxExecutor(seed=0), pool_pages=96)
    h = cluster.submit(Application.serve(
        "tinyllama-1.1b", reduced=True, name="drain",
        serve=ServeOptions(backend="paged", max_batch=2, replicas=replicas,
                           pool_pages=96, cache_len=512)))
    reqs = [Request(f"r{i}", 40 + 7 * i, max_new_tokens=8) for i in range(4)]
    for r in reqs:
        h.submit_request(r)
    receipt = None
    if drain_after is not None:
        for _ in range(drain_after):
            h.step()
        receipt = h.remove_replica()
    h.run(max_steps=500)
    toks = {r.req_id: list(r.output_tokens) for r in reqs}
    h.release()
    return toks, receipt


def test_replica_drain_token_identical_migration():
    """Mid-decode scale-in migrates in-flight requests to a survivor and
    the continuation is token-identical: replicas decode through one
    shared physical KV array set, so drained KV re-grants in place."""
    ref, _ = _paged_tokens(replicas=1)
    got, receipt = _paged_tokens(replicas=3, drain_after=3)
    assert receipt["migrated_requests"] >= 1, receipt
    assert all(len(t) > 8 for t in got.values())   # prefill token + decode
    assert got == ref


def test_dense_drain_falls_back_to_requeue():
    """The dense backend has no migratable page identity: scale-in
    requeues the victim's work at the router front (at-least-once,
    deterministic re-execution) instead of moving KV."""
    cluster = Cluster(pods=1, history=HistoryStore(),
                      executor=JaxExecutor(seed=0), pool_pages=64)
    h = cluster.submit(Application.serve(
        "tinyllama-1.1b", reduced=True, name="dense-drain",
        serve=ServeOptions(backend="dense", max_batch=2, replicas=2)))
    reqs = [Request(f"d{i}", 16 + 5 * i, max_new_tokens=4) for i in range(3)]
    for r in reqs:
        h.submit_request(r)
    for _ in range(2):
        h.step()
    receipt = h.remove_replica()
    assert receipt["migrated_requests"] == 0
    assert receipt["requeued_requests"] >= 1
    h.run(max_steps=500)
    assert aggregate_engine_stats(h).completed == 3
    assert all(len(r.output_tokens) > 4 for r in reqs)
    h.release()


def test_remove_last_replica_is_refused():
    cluster = _null_cluster()
    h = _serve(cluster, "last", max_batch=2)
    with pytest.raises(RuntimeError, match="park"):
        h.remove_replica()
    h.release()


# ---------------------------------------------------------------------------
# scale-to-zero == park
# ---------------------------------------------------------------------------

def test_scale_to_zero_is_park_round_trip():
    cluster = _null_cluster()
    h = _serve(cluster, "zero", max_batch=2, replicas=2,
               scale=ScalePolicy(min_replicas=0, max_replicas=3))
    for r in _reqs(4, prefix="a"):
        h.submit_request(r)
    h.run(max_steps=1000)
    assert h.num_replicas == 2

    receipt = h.park()
    assert h.parked and h.num_replicas == 0
    # park first scaled the set to one replica (nothing in flight here,
    # so nothing to migrate), then drained it
    assert receipt["migrated_requests"] == 0
    assert len(h.replica_set.replicas) == 1

    # demand-driven restart: submit lands on a live engine again
    for r in _reqs(2, prefix="b"):
        h.submit_request(r)
    assert not h.parked and h.num_replicas == 1
    h.add_replica()
    h.run(max_steps=1000)
    # retired-replica counters folded in: totals stay monotonic
    assert aggregate_engine_stats(h).completed == 6
    h.release()


# ---------------------------------------------------------------------------
# autoscaled replica count / batch width
# ---------------------------------------------------------------------------

def test_autoscaler_adds_replicas_on_queue_depth():
    tracer = obs.enable()
    cluster = _null_cluster()
    h = _serve(cluster, "scaleout", max_batch=2,
               scale=ScalePolicy(max_replicas=3,
                                 target_queue_per_replica=1.0))
    ctl = cluster.enable_autoscale(confirm_ticks=1, idle_park_s=1e9)
    for r in _reqs(8):
        h.submit_request(r)
    cluster.tick(now=0.0)
    cluster.tick(now=1.0)

    actions = [a["action"] for a in ctl.log]
    assert "add_replica" in actions, actions
    assert h.num_replicas >= 2
    # acceptance: scale decisions land in the trace WITH windowed rates
    decisions = tracer.by_name("decision", "autoscale")
    assert decisions
    assert any(k.startswith("rate_") for k in decisions[0][6])
    assert tracer.by_name("replica_add", "autoscale")
    h.run(max_steps=1000)
    assert aggregate_engine_stats(h).completed == 8
    h.release()


def test_autoscaler_widens_batch_on_occupancy():
    cluster = _null_cluster()
    h = _serve(cluster, "widen", max_batch=2,
               scale=ScalePolicy(batch_max=8))
    ctl = cluster.enable_autoscale(confirm_ticks=1, idle_park_s=1e9)
    for r in _reqs(6):
        h.submit_request(r)
    h.step()                    # both slots busy: occupancy 1.0, queue > 0
    cluster.tick(now=0.0)       # baseline observation
    cluster.tick(now=1.0)
    grown = [a for a in ctl.log if a["action"] == "grow_batch"]
    assert grown, ctl.log
    assert h.replica_set.max_batch == 4      # doubled, inside batch_max
    h.run(max_steps=1000)
    assert aggregate_engine_stats(h).completed == 6
    h.release()


def test_predictive_unpark_wakes_before_forecast_arrival():
    """A periodic tenant parked between bursts is warm-restarted
    ``unpark_lead_s`` ahead of the EWMA-forecast next arrival."""
    cluster = _null_cluster()
    h = _serve(cluster, "periodic", max_batch=2,
               scale=ScalePolicy(min_replicas=0, max_replicas=1))
    ctl = cluster.enable_autoscale(confirm_ticks=1, idle_park_s=1e9)
    for i, t in enumerate((0.0, 10.0, 20.0)):   # arrivals every 10s
        h.submit_request(Request(f"p{i}", PAGE_SIZE - 4, 4))
        h.run(max_steps=200)
        cluster.tick(now=t)
    h.park()
    assert h.parked

    cluster.tick(now=25.0)                      # well before the forecast
    assert h.parked
    cluster.tick(now=29.5)                      # 29.5 + lead 1.0 >= due 30.0
    assert not h.parked
    assert "unpark" in [a["action"] for a in ctl.log]
    h.release()


# ---------------------------------------------------------------------------
# stats surface
# ---------------------------------------------------------------------------

def test_stats_view_aggregates_replicas():
    cluster = _null_cluster()
    h = _serve(cluster, "sv", max_batch=2, replicas=3)
    view = h.stats_view
    mark = view.mark()
    for r in _reqs(9):
        h.submit_request(r)
    h.run(max_steps=1000)

    cum = view.cumulative()
    assert cum["completed"] == 9
    names = [rep["view"] for rep in cum["replicas"]]
    assert names == ["sv", "sv@r1", "sv@r2"]
    assert sum(rep["completed"] for rep in cum["replicas"]) == 9
    assert cum["router"]["dispatched"] == 9

    win = view.windowed(mark)
    assert win["completed"] == 9
    assert win["router"]["submitted"] == 9
    # a windowed result is not a marker
    with pytest.raises(ValueError, match="RAW snapshot"):
        view.windowed(win)

    # scale-down retires an engine; aggregated totals stay monotonic
    h.remove_replica()
    assert view.cumulative()["completed"] == 9
    h.release()
