"""ServeOptions / ScalePolicy: the typed serve API surface.

Construction-time validation (cross-field rules that used to fail deep
in bind), the one-release kwargs deprecation shim, and the
``Application.options`` mirror the executors still read.
"""

import pytest

from repro.runtime import Application, ScalePolicy, ServeOptions


# ---------------------------------------------------------------------------
# ServeOptions validation
# ---------------------------------------------------------------------------

def test_defaults_are_valid():
    o = ServeOptions()
    assert o.backend == "dense" and o.replicas == 1 and o.scale is None


@pytest.mark.parametrize("kw,match", [
    ({"backend": "sparse"}, "backend"),
    ({"prefix_cache": True}, "backend"),          # dense + prefix cache
    ({"replicas": 0}, "replicas"),
    ({"replicas": 2, "private_pool": True}, "private_pool"),
    ({"max_batch": 0}, "max_batch"),
    ({"policy": "generous"}, "policy"),
    ({"weight": 0.0}, "weight"),
    ({"replicas": 4, "scale": ScalePolicy(max_replicas=2)},
     "max_replicas"),
])
def test_rejects_bad_combinations(kw, match):
    with pytest.raises(ValueError, match=match):
        ServeOptions(**kw)


def test_prefix_cache_needs_paged_moved_out_of_build_runner():
    """The dense/prefix-cache rejection now fires at option-construction
    time, where the traceback points at the caller's line."""
    with pytest.raises(ValueError, match="backend"):
        ServeOptions(backend="dense", prefix_cache=True)
    ServeOptions(backend="paged", prefix_cache=True)   # fine


@pytest.mark.parametrize("kw,match", [
    ({"min_replicas": -1}, "min_replicas"),
    ({"min_replicas": 3, "max_replicas": 2}, "max_replicas"),
    ({"batch_min": 0}, "batch_min"),
    ({"batch_min": 4, "batch_max": 2}, "batch_max"),
    ({"shrink_occupancy": 0.9, "grow_occupancy": 0.5}, "occupancy"),
    ({"unpark_lead_s": -1.0}, "unpark_lead_s"),
])
def test_scale_policy_validation(kw, match):
    with pytest.raises(ValueError, match=match):
        ScalePolicy(**kw)


def test_scale_policy_dimension_flags():
    assert not ScalePolicy().scales_replicas
    assert ScalePolicy(max_replicas=3).scales_replicas
    assert ScalePolicy(min_replicas=0).scales_replicas   # scale-to-zero
    assert not ScalePolicy().scales_batch
    assert ScalePolicy(batch_max=8).scales_batch


def test_from_kwargs_rejects_unknown_keys():
    with pytest.raises(TypeError, match="max_batches"):
        ServeOptions.from_kwargs({"max_batches": 4})    # typo


def test_options_are_frozen():
    o = ServeOptions(max_batch=4)
    with pytest.raises(AttributeError):
        o.max_batch = 8


# ---------------------------------------------------------------------------
# Application.serve integration: typed path, shim, mirror
# ---------------------------------------------------------------------------

def test_serve_typed_path_no_warning(recwarn):
    app = Application.serve("tinyllama-1.1b", reduced=True,
                            serve=ServeOptions(max_batch=4, replicas=2))
    assert app.serve_options.replicas == 2
    assert not [w for w in recwarn.list
                if issubclass(w.category, DeprecationWarning)]


def test_serve_legacy_kwargs_deprecated_but_equivalent():
    with pytest.warns(DeprecationWarning, match="max_batch"):
        legacy = Application.serve("tinyllama-1.1b", reduced=True,
                                   max_batch=4, backend="paged",
                                   pool_pages=32)
    typed = Application.serve("tinyllama-1.1b", reduced=True,
                              serve=ServeOptions(max_batch=4,
                                                 backend="paged",
                                                 pool_pages=32))
    assert legacy.serve_options == typed.serve_options
    assert legacy.options == typed.options


def test_serve_rejects_mixing_serve_and_kwargs():
    with pytest.raises(TypeError, match="not both"):
        Application.serve("tinyllama-1.1b", reduced=True,
                          serve=ServeOptions(), max_batch=4)


def test_options_dict_mirrors_typed_surface():
    """Executors read ``opts`` via ServeOptions; the legacy ``options``
    dict stays populated for anything still introspecting it."""
    app = Application.serve("tinyllama-1.1b", reduced=True,
                            serve=ServeOptions(max_batch=4, weight=2.0))
    assert app.options["max_batch"] == 4
    assert app.options["weight"] == 2.0
    assert app.options == app.serve_options.asdict()


def test_from_callable_serve_passthrough():
    from repro.core.annotations import app_limit

    @app_limit(max_hbm_bytes=1 << 30)
    def my_app():
        from repro.configs import get_config
        from repro.configs.reduced import reduced_config
        return reduced_config(get_config("tinyllama-1.1b"))

    app = Application.from_callable(my_app, kind="serve",
                                    shape="decode_32k",
                                    serve=ServeOptions(max_batch=2))
    assert app.serve_options.max_batch == 2
    with pytest.raises(TypeError, match="kind='serve'"):
        Application.from_callable(my_app, kind="train",
                                  serve=ServeOptions())
