"""The repro.autoscale control plane: windowed stats semantics, metrics
windows, scaling policies, quota rebalancing, the tick-driven controller,
and idle-app parking (accounting exactness + token-identical warm
restart on both serving backends)."""

import pytest

from repro.autoscale import (IdleParker, MetricsWindow, QuotaRebalancer,
                             TargetTracking, stats_delta)
from repro.core.history import HistoryStore
from repro.core.scheduler import PodState
from repro.runtime import (Application, Cluster, JaxExecutor, NullExecutor,
                           ScalePolicy, ServeOptions)
from repro.serving.engine import EngineStats, ServingEngine
from repro.serving.kv_cache import PAGE_SIZE, Request
from repro.serving.tenancy import SharedPagePool


# ---------------------------------------------------------------------------
# windowed/delta stats semantics (cumulative counters -> per-window)
# ---------------------------------------------------------------------------

def test_engine_stats_snapshot_delta_reset():
    s = EngineStats(admitted=10, completed=7, decode_steps=100,
                    ttft_s_sum=2.0, ttft_count=10, decode_s_sum=5.0)
    snap = s.snapshot()
    s.admitted, s.completed = 14, 9
    s.ttft_s_sum, s.ttft_count = 2.8, 14
    d = s.delta(snap)
    assert d.admitted == 4 and d.completed == 2
    assert d.ttft_count == 4
    assert d.mean_ttft_s == pytest.approx(0.8 / 4)
    # lifetime stats untouched by delta
    assert s.admitted == 14
    # reset() zeroes counters in place and hands back the old window
    old = s.reset()
    assert old.admitted == 14 and s.admitted == 0 and s.ttft_s_sum == 0.0


def test_serving_stats_since_marker():
    cluster = Cluster(pods=1, executor=NullExecutor(), pool_pages=64)
    h = cluster.submit(Application.serve(
        "tinyllama-1.1b", reduced=True, name="windowed",
        serve=ServeOptions(max_batch=4)))
    for i in range(4):
        h.submit_request(Request(f"r{i}", 16, 4))
    while h.step()["alive"]:
        pass
    mark = h.serving_stats()
    assert mark["completed"] == 4
    for i in range(4, 6):
        h.submit_request(Request(f"r{i}", 16, 4))
    while h.step()["alive"]:
        pass
    win = h.serving_stats(since=mark)
    assert win["completed"] == 2, "windowed counter, not lifetime"
    assert win["admitted"] == 2
    # gauges stay absolute
    assert win["pool_quota_pages"] == mark["pool_quota_pages"]
    # pool counters are windowed too
    assert win["pool"]["grants"] == 2
    total = h.serving_stats()
    assert total["completed"] == 6, "since= must not mutate lifetime stats"
    # a windowed result is refused as a marker (delta-of-delta garbage)
    assert win["windowed"] and not total["windowed"]
    with pytest.raises(ValueError, match="RAW snapshot"):
        h.serving_stats(since=win)
    h.release()


def test_stats_delta_shared_pool_tallies():
    cur = {"admitted": 5, "completed": 5, "rejected": 0, "preempted": 0,
           "decode_steps": 10, "prefills": 5, "tokens_generated": 20,
           "ttft_s_sum": 1.0, "ttft_count": 5, "decode_s_sum": 0.5,
           "pool": {"grants": 5, "denials": 3, "grant_pages": 9,
                    "scaleups": 1, "released": 5},
           "shared_pool": {"num_pages": 64, "used_pages": 4,
                           "utilization": 0.06,
                           "denials_by_app": {"a": 3, "b": 1},
                           "preemptions_by_app": {"a": 2},
                           "cross_app_preemptions": 2}}
    since = {"admitted": 3, "completed": 3, "ttft_s_sum": 0.4,
             "ttft_count": 3, "decode_steps": 4, "decode_s_sum": 0.2,
             "pool": {"grants": 3, "denials": 1},
             "shared_pool": {"denials_by_app": {"a": 1},
                             "preemptions_by_app": {},
                             "cross_app_preemptions": 1}}
    d = stats_delta(cur, since)
    assert d["admitted"] == 2 and d["pool"]["denials"] == 2
    assert d["mean_ttft_s"] == pytest.approx(0.6 / 2)
    assert d["shared_pool"]["denials_by_app"] == {"a": 2, "b": 1}
    assert d["shared_pool"]["cross_app_preemptions"] == 1
    assert d["shared_pool"]["num_pages"] == 64      # gauge passthrough


def test_metrics_window_rates_and_idle():
    w = MetricsWindow(alpha=1.0)       # no smoothing: exact windows

    def stats(admitted, denials, queue_len=0, running=0):
        return {"admitted": admitted, "completed": 0, "rejected": 0,
                "preempted": 0, "decode_steps": admitted, "prefills": 0,
                "tokens_generated": admitted * 2, "ttft_s_sum": 0.0,
                "ttft_count": 0, "decode_s_sum": 0.0,
                "queue_len": queue_len, "num_running": running,
                "pool": {"grants": 0, "grant_pages": 0, "denials": denials,
                         "scaleups": 0, "released": 0},
                "pool_utilization": 0.5, "pool_used_pages": 4,
                "pool_quota_pages": 8}

    w.observe(stats(0, 0), now=0.0)                 # baseline
    w.observe(stats(4, 2), now=2.0)                 # 4 admits, 2 denials / 2s
    assert w.rates["admitted_per_s"] == pytest.approx(2.0)
    assert w.rates["denials_per_s"] == pytest.approx(1.0)
    assert w.rates["tokens_per_s"] == pytest.approx(4.0)
    assert w.idle_s == 0.0                          # traffic seen
    w.observe(stats(4, 2), now=3.0)                 # no deltas: idle
    assert w.idle_s == pytest.approx(1.0)
    w.observe(stats(4, 2, queue_len=1), now=4.0)    # queued work = active
    assert w.idle_s == 0.0


# ---------------------------------------------------------------------------
# policies
# ---------------------------------------------------------------------------

def _handle_with_traffic(cluster=None, name=None, **opts):
    cluster = cluster or Cluster(pods=1, history=HistoryStore(),
                                 executor=NullExecutor(), pool_pages=32)
    h = cluster.submit(Application.serve(
        "tinyllama-1.1b", reduced=True, name=name,
        serve=ServeOptions(max_batch=4, **opts)))
    return cluster, h


def test_target_tracking_scale_directions():
    _, h = _handle_with_traffic(name="tt")
    pol = TargetTracking(denial_target_per_s=1.0, shrink_utilization=0.25)
    w = MetricsWindow(alpha=1.0)
    w.rates = {"denials_per_s": 5.0, "pool_utilization": 0.9}
    up = pol.decide(w, h)
    assert up.action == "scale_up" and up.amount_bytes > 0
    w.rates = {"denials_per_s": 0.0, "pool_utilization": 0.05}
    down = pol.decide(w, h)
    assert down.action == "scale_down"
    # growth is capped: a demand already at max_demand_factor x estimate
    # must not grow further on the same signal
    h.job.demand_bytes = int(2.0 * h.app.capped_demand(
        h.app.estimate_demand()))
    w.rates = {"denials_per_s": 5.0, "pool_utilization": 0.9}
    assert pol.decide(w, h).action == "none"
    # an EWMA denial residue (never exactly 0) must not block shrink
    h.job.demand_bytes = h.app.estimate_demand()
    w.rates = {"denials_per_s": 0.01, "pool_utilization": 0.05}
    assert pol.decide(w, h).action == "scale_down"


def test_idle_parker_requires_sustained_idle():
    _, h = _handle_with_traffic(name="ip")
    pol = IdleParker(idle_s=10.0)
    w = MetricsWindow()
    w.now, w.last_active_t = 100.0, 95.0
    w.rates = {"queue_len": 0, "num_running": 0}
    assert pol.decide(w, h).action == "none"        # only 5s idle
    w.last_active_t = 85.0
    assert pol.decide(w, h).action == "park"
    w.rates = {"queue_len": 1, "num_running": 0}    # queued work: no park
    assert pol.decide(w, h).action == "none"


# ---------------------------------------------------------------------------
# runtime quota resize (the rebalancer's lever)
# ---------------------------------------------------------------------------

def test_resize_quota_shrink_drains_via_preemption():
    """Shrinking a view's quota below current usage must preempt (pages
    released + requests re-queued), never strand pages on the view."""
    shared = SharedPagePool(32)
    view = shared.view("shrink-me", quota=16, policy="fixed",
                       fixed_init_pages=2, fixed_step_pages=1)
    eng = ServingEngine(view, max_batch=4)
    for i in range(4):
        eng.submit(Request(f"r{i}", PAGE_SIZE * 2 - 4, 8))
    eng.step()
    assert view.used == 8
    preempted = view.resize_quota(3)
    assert preempted >= 1
    assert view.used <= 3, "usage must drain below the new quota"
    assert shared.used_pages == view.used, "pages stranded on the view"
    assert eng.stats.preempted == preempted
    # requests still complete under the smaller quota (requeued, 2 pages
    # each <= quota 3)
    stats = eng.run_to_completion(max_steps=10_000)
    assert stats.completed == 4
    assert shared.used_pages == 0


def test_quota_rebalancer_tracks_demand():
    hist = HistoryStore()
    shared = SharedPagePool(64, history=hist)
    busy = shared.view("busy", quota=21, policy="fixed")
    idle = shared.view("idle", quota=21, policy="fixed")
    eng_busy = ServingEngine(busy, max_batch=8)
    ServingEngine(idle, max_batch=8)
    for i in range(6):
        eng_busy.submit(Request(f"b{i}", PAGE_SIZE * 2 - 4, 64))
    for _ in range(3):
        eng_busy.step()
    assert busy.used >= 6
    reb = QuotaRebalancer(alpha=1.0, headroom=2.0, min_pages=2)
    wb, wi = MetricsWindow(), MetricsWindow()
    wb.window = {"pool": {"denials": 0}}
    wi.window = {"pool": {"denials": 0}}
    quotas = reb.rebalance(shared, {"busy": wb, "idle": wi})
    assert quotas["busy"] > quotas["idle"], \
        "busy tenant must out-provision the idle one"
    assert busy.quota == quotas["busy"]
    assert idle.quota == quotas["idle"]
    # idle tenant's provisioned quota collapsed toward the floor
    assert quotas["idle"] <= 4


# ---------------------------------------------------------------------------
# parking: accounting exactness
# ---------------------------------------------------------------------------

def test_park_releases_pages_and_bytes():
    cluster = Cluster(pods=1, history=HistoryStore(),
                      executor=NullExecutor(), pool_pages=32)
    h = cluster.submit(Application.serve(
        "tinyllama-1.1b", reduced=True, name="parkme",
        serve=ServeOptions(max_batch=4)))
    free0 = cluster.capacity()["pod0"]["free_bytes"]
    demand = h.job.demand_bytes
    assert demand > 0
    for i in range(3):
        h.submit_request(Request(f"r{i}", PAGE_SIZE * 2 - 4, 200))
    for _ in range(3):
        h.step()
    shared = cluster.pod_pool("pod0")
    pages_held = shared.used_pages
    assert pages_held > 0
    receipt = h.park()
    # >= 90% of accounted pool pages and scheduler bytes released
    assert receipt["freed_pages"] == pages_held
    assert shared.used_pages == 0
    assert receipt["freed_bytes"] >= 0.9 * demand
    assert h.job.demand_bytes == 0
    cap = cluster.capacity()["pod0"]
    assert cap["free_bytes"] == free0 + demand
    assert cap["reserved_bytes"] >= demand, "park pre-marks a reservation"
    assert h.parked
    assert h.step() == {"alive": False, "stats": h.engine.stats,
                        "parked": True}
    # a parked view must not dilute co-tenant fair shares
    view = h.engine.pool
    assert shared.fair_share(view) == 0.0
    h.unpark()
    assert not h.parked and h.job.demand_bytes == demand
    assert shared.used_pages == pages_held, "pages re-granted"
    stats = h.run(max_steps=50_000)
    assert stats["completed"] == 3
    h.release()


def test_park_unpark_cycles_no_byte_leak():
    """N park/unpark cycles against GlobalScheduler reservation
    accounting: free/reserved bytes and the shared pool free list must
    be exactly restored every cycle (the satellite regression)."""
    cluster = Cluster(pods=1, history=HistoryStore(),
                      executor=NullExecutor(), pool_pages=16)
    h = cluster.submit(Application.serve(
        "tinyllama-1.1b", reduced=True, name="cycler",
        serve=ServeOptions(max_batch=2)))
    for i in range(2):
        h.submit_request(Request(f"r{i}", PAGE_SIZE - 4, 400))
    for _ in range(2):
        h.step()
    pod = cluster.scheduler.pods["pod0"].pod
    shared = cluster.pod_pool("pod0")
    free0, reserved0 = pod.free_bytes, pod.reserved_bytes
    used0, demand0 = shared.used_pages, h.job.demand_bytes
    for cycle in range(5):
        h.park()
        assert pod.free_bytes == free0 + demand0
        assert pod.reserved_bytes == reserved0 + demand0
        assert shared.used_pages == 0
        h.unpark()
        assert pod.free_bytes == free0, f"byte leak after cycle {cycle}"
        assert pod.reserved_bytes == reserved0
        assert shared.used_pages == used0
        assert h.job.demand_bytes == demand0
    stats = h.run(max_steps=50_000)
    assert stats["completed"] == 2
    h.release()
    assert pod.free_bytes == pod.num_chips * pod.hbm_per_chip
    assert pod.reserved_bytes == 0


def test_parked_reservation_is_low_priority():
    """Another app may take a parked app's space; unpark then fails
    loudly instead of corrupting accounting."""
    demand = 1 << 20
    cluster = Cluster(pods=[PodState("pod0", 1, 2 * demand)],
                      executor=NullExecutor(), pool_pages=8)
    a = cluster.submit(Application.synthetic("a", "serve", demand))
    # synthetic apps skip executor binding; give the handle an engine so
    # the parking path has something to drain
    a.exec_state["engine"] = ServingEngine(cluster.pod_pool("pod0").view("a"))
    a.park()
    assert cluster.capacity()["pod0"]["free_bytes"] == 2 * demand
    b = cluster.submit(Application.synthetic("b", "serve", 2 * demand))
    assert b.state == "running", "reservation must be low-priority"
    with pytest.raises(RuntimeError, match="cannot unpark"):
        a.unpark()
    assert a.parked, "failed unpark must leave the app parked"
    b.release()
    a.unpark()                        # capacity back: now it works
    assert a.job.demand_bytes == demand
    a.release()


def test_park_release_does_not_poison_sizing_history():
    """Releasing a parked app (demand ground to 0) must record the
    working footprint into job-bytes history, not the residual zero --
    otherwise the next submission of this app is sized near 0."""
    hist = HistoryStore()
    cluster = Cluster(pods=1, history=hist, executor=NullExecutor(),
                      pool_pages=8)
    h = cluster.submit(Application.serve(
        "tinyllama-1.1b", reduced=True, name="poison",
        serve=ServeOptions(max_batch=2)))
    demand0 = h.job.demand_bytes
    h.park()
    assert h.job.demand_bytes == 0
    h.release()
    rec = hist.get("poison", "job", "bytes")
    assert rec is not None and rec.last == demand0


def test_default_policy_chain_parks_before_grinding_down():
    """The parker must outrank target-tracking shrink: a big app with
    many sizing steps of shrinkable headroom still parks as soon as the
    idle threshold passes, not after demand reaches the floor."""
    from repro.autoscale import default_policies
    chain = default_policies(idle_park_s=2.0)
    assert isinstance(chain[0], IdleParker)
    cluster = Cluster(pods=1, history=HistoryStore(),
                      executor=NullExecutor(), pool_pages=8)
    cluster.enable_autoscale(idle_park_s=2.0, confirm_ticks=1)
    # huge synthetic demand: thousands of 64 MiB shrink steps available
    h = cluster.submit(Application.serve(
        "tinyllama-1.1b", reduced=True, name="big",
        serve=ServeOptions(max_batch=2)))
    h.job.demand_bytes = 256 << 30
    cluster.scheduler.pods["pod0"].pod.free_bytes -= (256 << 30) - 213376
    for t in range(5):
        cluster.tick(now=float(t))
    assert h.parked, "must park at idle_s, not shrink step-by-step first"
    h.unpark()
    h.release()


def test_park_rejects_wrong_states():
    cluster = Cluster(pods=1, executor=NullExecutor(), pool_pages=8)
    t = cluster.submit(Application.train("tinyllama-1.1b", reduced=True))
    with pytest.raises(ValueError, match="serve"):
        t.park()
    t.release()
    s = cluster.submit(Application.serve("tinyllama-1.1b", reduced=True))
    s.park()
    with pytest.raises(RuntimeError, match="already parked"):
        s.park()
    s.release()


# ---------------------------------------------------------------------------
# parking: token-identical warm restart (both backends)
# ---------------------------------------------------------------------------

def _serve_with_park(backend, park_cycles, *, n=3, prompt=200, max_new=8,
                     arch="tinyllama-1.1b", steps_before_park=3):
    cluster = Cluster(pods=1, history=HistoryStore(),
                      executor=JaxExecutor(seed=0))
    h = cluster.submit(Application.serve(
        arch, reduced=True, name=f"park-{backend}",
        serve=ServeOptions(max_batch=4, pool_pages=32, cache_len=512,
                           policy="history", backend=backend)))
    reqs = [Request(f"r{i}", prompt_len=prompt, max_new_tokens=max_new)
            for i in range(n)]
    for r in reqs:
        h.submit_request(r)
    for _ in range(steps_before_park):  # partial progress, then park
        h.step()
    for _ in range(park_cycles):
        h.park()
        assert h.runner.params is None, "params must be offloaded to host"
        h.unpark()
        assert h.runner.params is not None
    stats = h.run(max_steps=5_000)
    tokens = {r.req_id: list(r.output_tokens) for r in reqs
              if r.output_tokens is not None}
    h.release()
    return stats, tokens


@pytest.mark.parametrize("backend", ["dense", "paged"])
def test_unpark_decode_token_identical(backend):
    """An unparked app's decode must be token-identical to one that was
    never parked (same seed): the drained KV really is restored, not
    recomputed approximately."""
    s0, t0 = _serve_with_park(backend, park_cycles=0)
    s1, t1 = _serve_with_park(backend, park_cycles=1)
    s2, t2 = _serve_with_park(backend, park_cycles=3)
    assert s0["completed"] == s1["completed"] == s2["completed"] == 3
    assert t0 == t1 == t2, f"{backend}: tokens diverged after park/unpark"
    assert all(len(t) == 9 for t in t1.values())    # prefill + 8 decodes


def test_unpark_swa_ring_token_identical():
    """N park/unpark cycles for a sliding-window tenant (reduced gemma3,
    paged backend): the local-layer ring contents must survive the
    re-grant -- fresh ring page ids, identical tokens."""
    # 60 steps of progress first: length 200+59 > 256-token ring space,
    # so the parked rings hold WRAPPED state when they are snapshot
    s0, t0 = _serve_with_park("paged", park_cycles=0, arch="gemma3-12b",
                              prompt=200, max_new=70, steps_before_park=60)
    s3, t3 = _serve_with_park("paged", park_cycles=3, arch="gemma3-12b",
                              prompt=200, max_new=70, steps_before_park=60)
    assert s0["completed"] == s3["completed"] == 3
    assert t0 == t3, "SWA ring contents diverged across park/unpark"
    assert all(len(t) == 71 for t in t3.values())   # prefill + 70 decodes


def test_unpark_under_pool_pressure():
    """Co-tenants consumed the pool while the app was parked: unpark
    must still restore via the pool's arbitration (cross-app fair-share
    preemption), and whatever cannot be restored falls back to re-queue
    + re-execution -- never stranding pages, never losing requests."""
    cluster = Cluster(pods=1, history=HistoryStore(),
                      executor=NullExecutor(), pool_pages=8)
    a = cluster.submit(Application.serve(
        "tinyllama-1.1b", reduced=True, name="parked",
        serve=ServeOptions(max_batch=2)))
    b = cluster.submit(Application.serve(
        "tinyllama-1.1b", reduced=True, name="squatter",
        serve=ServeOptions(max_batch=8)))
    for i in range(2):
        a.submit_request(Request(f"a{i}", PAGE_SIZE * 2 - 4, 60))
    for _ in range(2):
        a.step()
    a.park()
    for i in range(8):                  # squatter grabs the whole pool
        b.submit_request(Request(f"b{i}", PAGE_SIZE - 4, 60))
    for _ in range(3):
        b.step()
    assert len(cluster.pod_pool("pod0").free) == 0
    info = a.unpark()
    assert info["restored_requests"] + info["requeued_requests"] == 2
    # whatever happened, accounting stays exact and work finishes
    for _ in range(50_000):
        alive_a = a.step()["alive"]
        alive_b = b.step()["alive"]
        if not (alive_a or alive_b):
            break
    assert a.serving_stats()["completed"] == 2
    assert b.serving_stats()["completed"] == 8
    a.release()
    b.release()
    assert sorted(cluster.pod_pool("pod0").free) == list(range(8))


# ---------------------------------------------------------------------------
# the controller end-to-end
# ---------------------------------------------------------------------------

def test_controller_parks_idle_app_and_unparks_on_submit():
    cluster = Cluster(pods=1, history=HistoryStore(),
                      executor=NullExecutor(), pool_pages=32)
    cluster.enable_autoscale(idle_park_s=5.0, confirm_ticks=2)
    h = cluster.submit(Application.serve(
        "tinyllama-1.1b", reduced=True, name="ticker",
        serve=ServeOptions(max_batch=4)))
    for i in range(3):
        h.submit_request(Request(f"r{i}", 48, 8))
    t = 0.0
    while h.step()["alive"]:
        cluster.tick(now=t)
        t += 1.0
    assert not h.parked
    for _ in range(12):                 # idle ticks
        cluster.tick(now=t)
        t += 1.0
    assert h.parked, "idle app must be parked by the tick loop"
    parks = [a for a in cluster.autoscaler.log if a["action"] == "park"]
    assert len(parks) == 1 and parks[0]["app"] == "ticker"
    h.submit_request(Request("wake", 48, 8))
    assert not h.parked, "submit_request must transparently unpark"
    stats = h.run(max_steps=50_000)
    assert stats["completed"] == 4
    h.release()


def test_controller_hysteresis_and_cooldown():
    cluster = Cluster(pods=1, history=HistoryStore(),
                      executor=NullExecutor(), pool_pages=16)
    ctl = cluster.enable_autoscale(denial_target_per_s=0.5,
                                   confirm_ticks=3, cooldown_up_s=10.0)
    h = cluster.submit(Application.serve(
        "tinyllama-1.1b", reduced=True, name="hyst",
        serve=ServeOptions(max_batch=4, quota_pages=2)))
    # quota-starved traffic produces a sustained denial signal (each
    # request fits the 2-page quota, but concurrency does not)
    for i in range(6):
        h.submit_request(Request(f"r{i}", PAGE_SIZE - 4, 130))
    ups = []
    for t in range(8):
        for _ in range(2):
            h.step()
        ups += [a for a in ctl.tick(now=float(t))
                if a["action"] == "scale_up"]
    # confirm_ticks=3 delays the first action to the 3rd confirming
    # tick; cooldown_up_s=10 then allows no second one within 8 ticks
    assert len(ups) == 1, ups
    assert ups[0]["t"] >= 2.0
    h.release()


def test_controller_never_scales_a_parked_app():
    """Decaying pre-park signals (denial EWMA) must not drive scale_up
    on a parked handle -- that would consume the park reservation and
    break the demand_bytes==0 parked invariant."""
    cluster = Cluster(pods=1, history=HistoryStore(),
                      executor=NullExecutor(), pool_pages=4)
    ctl = cluster.enable_autoscale(idle_park_s=3.0, confirm_ticks=1,
                                   denial_target_per_s=0.5)
    h = cluster.submit(Application.serve(
        "tinyllama-1.1b", reduced=True, name="spiky",
        serve=ServeOptions(max_batch=4, quota_pages=2)))
    for i in range(4):      # quota-starved: builds a strong denial EWMA
        h.submit_request(Request(f"r{i}", PAGE_SIZE - 4, 130))
    t = 0.0
    while h.step()["alive"]:
        cluster.tick(now=t)
        t += 1.0
    for _ in range(10):     # idle: parks, then EWMA keeps decaying
        cluster.tick(now=t)
        t += 1.0
    assert h.parked
    assert h.job.demand_bytes == 0, \
        "scale policies acted on a parked app"
    assert not any(a["action"] in ("scale_up", "scale_down")
                   and a["t"] > next(x["t"] for x in ctl.log
                                     if x["action"] == "park")
                   for a in ctl.log if "t" in a)
    h.release()


def test_rebalancer_demand_scoped_per_pod():
    """One rebalancer serves every pod; same-named tenants on different
    pods must not share a demand EWMA."""
    reb = QuotaRebalancer(alpha=0.5, headroom=2.0, min_pages=2)
    pod0, pod1 = SharedPagePool(64), SharedPagePool(64)
    for shared, used in ((pod0, 20), (pod1, 0)):
        api = shared.view("api", quota=16, policy="fixed")
        other = shared.view("other", quota=16, policy="fixed")
        api.used = used                  # direct accounting for the test
        ServingEngine(api, max_batch=1)
        ServingEngine(other, max_batch=1)
    w = {"api": MetricsWindow(), "other": MetricsWindow()}
    q0 = reb.rebalance(pod0, w, scope="pod0")
    q1 = reb.rebalance(pod1, w, scope="pod1")
    assert q0["api"] >= 40, "busy pod0 tenant under-provisioned"
    assert q1["api"] <= 4, \
        "idle pod1 tenant inherited pod0's demand EWMA"
    # cross-talk check in the other direction too: pod0 stays high
    assert reb.rebalance(pod0, w, scope="pod0")["api"] >= 40


def test_disabled_autoscale_tick_is_noop():
    cluster = Cluster(pods=1, executor=NullExecutor())
    assert cluster.tick() == []
