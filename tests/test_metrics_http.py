"""Streaming /metrics endpoint (repro.obs.http): stdlib HTTP server
over the process-global Prometheus registry."""

import urllib.error
import urllib.request

import pytest

from repro import obs
from repro.core.history import HistoryStore
from repro.runtime import Application, Cluster, NullExecutor, ServeOptions
from repro.serving.kv_cache import PAGE_SIZE, Request


@pytest.fixture(autouse=True)
def _obs_off():
    obs.disable()
    obs.disable_metrics()
    yield
    obs.disable()
    obs.disable_metrics()


@pytest.fixture
def srv():
    s = obs.serve_metrics(port=0)       # ephemeral port
    yield s
    s.stop()


def _get(port, path="/metrics"):
    with urllib.request.urlopen(
            f"http://127.0.0.1:{port}{path}", timeout=5) as resp:
        return resp.status, resp.read().decode("utf-8")


def test_scrape_serves_live_registry(srv):
    obs.enable_metrics()
    cluster = Cluster(pods=1, history=HistoryStore(),
                      executor=NullExecutor(), pool_pages=16)
    h = cluster.submit(Application.serve(
        "tinyllama-1.1b", reduced=True, name="scrape",
        serve=ServeOptions(max_batch=2)))
    for i in range(3):
        h.submit_request(Request(f"m{i}", PAGE_SIZE - 4, 4))
    h.run(max_steps=200)

    status, body = _get(srv.port)
    assert status == 200
    assert "repro_" in body             # engine histograms made it out
    assert "# TYPE" in body             # Prometheus text exposition
    h.release()

    # "/" is an alias; query strings are ignored
    assert _get(srv.port, "/")[0] == 200
    assert _get(srv.port, "/metrics?x=1")[0] == 200


def test_scrape_before_enable_is_503(srv):
    # the global registry is not installed: scrapes get an explicit 503,
    # and the SAME server starts serving data once metrics are enabled
    with pytest.raises(urllib.error.HTTPError) as exc:
        _get(srv.port)
    assert exc.value.code == 503
    assert b"enable_metrics" in exc.value.read()

    reg = obs.enable_metrics()
    reg.inc("repro_scrapes_total", app="t")
    status, body = _get(srv.port)
    assert status == 200
    assert "repro_scrapes_total" in body


def test_unknown_path_is_404(srv):
    with pytest.raises(urllib.error.HTTPError) as exc:
        _get(srv.port, "/health")
    assert exc.value.code == 404


def test_explicit_registry_overrides_global():
    from repro.obs.metrics import MetricsRegistry
    reg = MetricsRegistry()
    reg.inc("repro_private_total")
    s = obs.serve_metrics(port=0, registry=reg)
    try:
        status, body = _get(s.port)
        assert status == 200
        assert "repro_private_total" in body
    finally:
        s.stop()


def test_stop_closes_listener_and_is_idempotent():
    s = obs.serve_metrics(port=0)
    port = s.port
    s.stop()
    s.stop()                            # second stop is a no-op
    with pytest.raises((urllib.error.URLError, ConnectionError, OSError)):
        _get(port)
