"""zensan: the shadow-ledger sanitizer must (a) stay silent on every
legal flow, (b) catch each seeded corruption BY NAME, and (c) cost
nothing when disabled.

The seeded tests are the sanitizer's own CI gate: a refactor that
silently stops a hook from firing turns one of these red, not a
production incident."""

import pytest

from repro.analysis import zensan
from repro.analysis.zensan import ZensanViolation
from repro.core.history import HistoryStore
from repro.serving.kv_cache import PAGE_SIZE, PagePool, Request
from repro.serving.prefix_cache import PrefixCache
from repro.serving.tenancy import SharedPagePool


@pytest.fixture
def san():
    """Strict sanitizer for the test body; restores whatever was
    installed before (the REPRO_ZENSAN=1 CI instance, usually None)."""
    prev = zensan.SAN
    s = zensan.enable(strict=True)
    yield s
    zensan._install(prev)


@pytest.fixture
def lax():
    """Non-strict: accumulate violations for inspection."""
    prev = zensan.SAN
    s = zensan.enable(strict=False)
    yield s
    zensan._install(prev)


def _pod(pages=16, apps=("a", "b")):
    shared = SharedPagePool(pages, history=HistoryStore())
    views = {app: shared.view(app, policy="fixed", fixed_init_pages=1,
                              fixed_step_pages=1) for app in apps}
    return shared, views


def _req(rid, pages=1, max_new=4):
    toks = tuple(range(pages * PAGE_SIZE))
    return Request(rid, len(toks), max_new_tokens=max_new,
                   prompt_tokens=toks)


# -- clean flows stay silent --------------------------------------------------

def test_clean_two_tenant_flow(san):
    shared, views = _pod()
    reqs = {}
    for app, v in views.items():
        r = _req(f"{app}0", pages=2)
        assert v.try_admit(r)
        reqs[app] = r
        san.check(v)
    for _ in range(3):                      # grow + check each step
        for app, v in views.items():
            v.grow(reqs[app], horizon=1)
            san.check(v)
    # park/unpark round trip for one tenant
    va = views["a"]
    phys, phys_local = va.reclaim(reqs["a"])
    va.parked = True
    san.check(va)
    va.parked = False
    assert va.regrant(reqs["a"], len(phys), len(phys_local))
    san.unpark_done(va, "a")
    san.check(va)
    for app, v in views.items():
        v.release(reqs[app])
        san.check(v)
        v.close()
    assert san.violations == [] and san.events > 0


def test_clean_null_engine_serving(san):
    """End-to-end through Cluster/ServingEngine with the null executor:
    every step's quiescent check stays green."""
    from repro.runtime.cluster import Application, Cluster
    from repro.runtime.executors import NullExecutor
    from repro.runtime.options import ServeOptions

    cluster = Cluster(pods=1, history=HistoryStore(),
                      executor=NullExecutor(), pool_pages=16)
    h = cluster.submit(Application.serve(
        "tinyllama-1.1b", reduced=True, name="zs",
        serve=ServeOptions(max_batch=2)))
    for i in range(3):
        h.submit_request(Request(f"r{i}", PAGE_SIZE - 4, 6))
    for _ in range(200):
        if not h.step()["alive"]:
            break
    h.park()
    h.unpark()
    h.release()
    assert san.violations == []


def test_clean_private_pool_prefix_flow(san):
    pool = PagePool(8, app="solo")
    pool.prefix_cache = PrefixCache(("solo",), pool._give)
    r = _req("p0", pages=2)
    assert pool.try_admit(r)
    phys = pool.cache_donate(r.pages[:1])
    del r.pages[:1]
    r.shared_pages = phys
    created = pool.prefix_cache.insert(r.prompt_tokens[:PAGE_SIZE], 0, phys)
    r.prefix_nodes = created
    san.check(pool)
    pool.release(r)
    san.check(pool)
    pool.prefix_cache.flush()
    san.check(pool)
    assert san.violations == []


# -- seeded corruptions are caught BY NAME ------------------------------------

def test_seeded_double_free(san):
    shared, views = _pod()
    v = views["a"]
    r = _req("df0", pages=2)
    assert v.try_admit(r)
    phys = v.to_physical(r.pages)
    v.release(r)                            # legal free
    with pytest.raises(ZensanViolation, match=r"double-free"):
        shared._give(phys)                  # the bug: freed again


def test_seeded_refcount_leak(san):
    pool = PagePool(8, app="leak")
    pool.prefix_cache = PrefixCache(("leak",), pool._give)
    r = _req("rl0", pages=1)
    assert pool.try_admit(r)
    phys = pool.cache_donate(list(r.pages))
    r.pages = []
    node = pool.prefix_cache.insert(r.prompt_tokens, 0, phys)[0]
    node.refs += 1                          # the bug: a pin bypassing pin()
    with pytest.raises(ZensanViolation, match=r"refcount-leak"):
        san.check(pool)


def test_seeded_quota_overdraft(san, monkeypatch):
    from repro.serving import tenancy

    def buggy_alloc(self, n):
        """PoolView._alloc with its quota guard deleted."""
        got = self.shared._take(n)
        if got is None:
            return None
        self.used += n
        ids = self._new_ids(n)
        for vid, pid in zip(ids, got):
            self._remap[vid] = pid
        s = zensan.SAN
        if s is not None:
            s.grant(self, ids, got)
        return ids

    monkeypatch.setattr(tenancy.PoolView, "_alloc", buggy_alloc)
    shared = SharedPagePool(16, history=HistoryStore())
    v = shared.view("a", quota=2, policy="fixed", fixed_init_pages=1,
                    fixed_step_pages=1)
    with pytest.raises(ZensanViolation, match=r"quota-overdraft"):
        v.try_admit(_req("qo0", pages=3))


def test_seeded_stranded_park_receipt(san):
    shared, views = _pod()
    v = views["a"]
    r = _req("sp0", pages=2)
    assert v.try_admit(r)
    v.reclaim(r)                            # park receipt recorded
    with pytest.raises(ZensanViolation, match=r"stranded-park-receipt"):
        san.unpark_done(v, "a")             # ...but never regranted


def test_seeded_park_mismatch(san):
    shared, views = _pod()
    v = views["a"]
    r = _req("pm0", pages=2)
    assert v.try_admit(r)
    phys, _ = v.reclaim(r)
    with pytest.raises(ZensanViolation, match=r"park-mismatch"):
        v.regrant(r, len(phys) + 1)         # the bug: wrong page count


def test_seeded_id_escape(san):
    """A view-local id reaching a decode table (the runtime twin of
    zenlint ZL001) is flagged against the ledger."""
    shared, views = _pod()
    v = views["a"]
    r = _req("ie0", pages=1)
    assert v.try_admit(r)
    with pytest.raises(ZensanViolation, match=r"id-escape"):
        san.table(v, [list(r.pages)], [])   # untranslated view-local ids
    # the translated row is fine
    san.table(v, [v.to_physical(r.pages)], [])


def test_seeded_view_leak(san):
    shared, views = _pod()
    v = views["a"]
    assert v.try_admit(_req("vl0", pages=2))
    with pytest.raises(ZensanViolation, match=r"view-leak"):
        v.close()                           # the bug: close holding pages


def test_seeded_conservation_diff(lax):
    """A page silently dropped from a view's remap shows up in the
    check() sweep with the ledger-vs-real diff attached."""
    shared, views = _pod()
    v = views["a"]
    r = _req("cv0", pages=2)
    assert v.try_admit(r)
    lax.check(v)
    assert lax.violations == []
    v._remap.popitem()                      # the bug: lost a page
    lax.check(v)
    rules = {x.rule for x in lax.violations}
    assert "conservation" in rules
    assert any("ledger" in x.diff for x in lax.violations if x.diff)


def test_seeded_dense_slot(san):
    from types import SimpleNamespace
    runner = SimpleNamespace(slots={}, generated={})
    with pytest.raises(ZensanViolation, match=r"dense-slot"):
        san.dense_state(runner, [_req("ds0")])


# -- bounded schedule explorer ------------------------------------------------

def test_explorer_depth2_clean():
    prev = zensan.SAN
    res = zensan.explore(depth=2)
    assert zensan.SAN is prev               # save/restore held
    assert res.sequences == len(zensan.EXPLORE_OPS) ** 2
    assert res.ops_applied == res.sequences * 2
    assert res.ok, "\n".join(v.render() for v in res.violations[:10])


def test_explorer_depth3_clean():
    res = zensan.explore(depth=3)
    assert res.sequences == len(zensan.EXPLORE_OPS) ** 3
    assert res.ok, "\n".join(v.render() for v in res.violations[:10])


def test_explorer_catches_seeded_model_bug(monkeypatch):
    """Sanity: the explorer is not vacuously green -- a model whose
    preempt 'forgets' to uncharge quota trips the ledger."""
    real_dealloc = None
    from repro.serving import tenancy

    real_dealloc = tenancy.PoolView._dealloc

    def buggy_dealloc(self, pages):
        self.used += len(pages)             # the bug: double-charge
        return real_dealloc(self, pages)

    monkeypatch.setattr(tenancy.PoolView, "_dealloc", buggy_dealloc)
    res = zensan.explore(depth=2, ops=("grant_a", "preempt_a"))
    assert not res.ok
    assert any(v.rule == "conservation" for v in res.violations)


# -- disabled: zero footprint -------------------------------------------------

def test_disabled_leaves_no_shadow_state():
    prev = zensan.SAN
    zensan._install(None)
    try:
        shared, views = _pod()
        v = views["a"]
        r = _req("z0", pages=2)
        assert v.try_admit(r)
        v.release(r)
        assert not hasattr(shared, "_zs_ledger")
        assert not hasattr(v, "_zs_local")
    finally:
        zensan._install(prev)


def test_enable_mid_flight_adopts_live_state():
    """enable() after unobserved mutations must re-snapshot, not
    complain about history it never saw."""
    prev = zensan.SAN
    zensan._install(None)
    try:
        shared, views = _pod()
        v = views["a"]
        r = _req("mf0", pages=2)
        assert v.try_admit(r)               # unobserved
        s = zensan.enable(strict=True)
        san_r2 = _req("mf1", pages=1)
        assert v.try_admit(san_r2)
        s.check(v)
        v.release(r)
        v.release(san_r2)
        s.check(v)
        assert s.violations == []
    finally:
        zensan._install(prev)
